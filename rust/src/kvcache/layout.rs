//! KV-cache layouts (paper Table 2) and their indexing math.
//!
//! A KV cache for one layer is a 4-dimensional array over
//! (Block, K/V, Token-in-block, Header); the three layouts order these
//! dimensions differently, which determines
//! (a) whether appending a page shifts existing data, and
//! (b) whether per-head migration segments are contiguous.
//!
//! | Layout               | Hierarchy                    | Benefit |
//! |----------------------|------------------------------|---------|
//! | Raw                  | [K/V, Block, Token, Header]  | —       |
//! | Page-friendly        | [Block, K/V, Token, Header]  | O(#pages)→0 shifting |
//! | Header-centric       | [Block, Header, K/V, Token]  | O(#tokens)→O(1) trim |
//!
//! The same stride orders are implemented by `kv_stride_order()` in
//! python/compile/kernels/attention_pallas.py; test_kernels.py checks the
//! two agree element-for-element.

/// One of the four logical dimensions of the KV cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dim {
    Block,
    Kv,
    Token,
    Header,
}

/// KV-cache layout variants from paper Table 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KvLayout {
    /// `[K/V, Block, Token, Header]` — K and V each contiguous across the
    /// whole cache (vLLM-style preallocated tensor).
    Raw,
    /// `[Block, K/V, Token, Header]` — block-major; pages append freely.
    PageFriendly,
    /// `[Block, Header, K/V, Token]` — additionally groups each head's
    /// K+V contiguously inside a block (Gyges).
    HeaderCentric,
}

/// Geometry of one layer's KV cache.
#[derive(Clone, Copy, Debug)]
pub struct KvGeometry {
    pub num_blocks: u64,
    pub tokens_per_block: u64,
    pub num_heads: u64,
    /// Bytes of one K (or V) vector for one token of one head.
    pub head_elem_bytes: u64,
}

impl KvGeometry {
    /// Total bytes of the cache.
    pub fn total_bytes(&self) -> u64 {
        self.num_blocks * self.block_bytes()
    }

    /// Bytes of one block.
    pub fn block_bytes(&self) -> u64 {
        2 * self.tokens_per_block * self.num_heads * self.head_elem_bytes
    }

    /// Bytes one head contributes to one block (its K and V).
    pub fn head_bytes_per_block(&self) -> u64 {
        2 * self.tokens_per_block * self.head_elem_bytes
    }
}

impl KvLayout {
    /// Dimension order, outermost first (paper Table 2 "Detailed Hierarchy").
    pub fn stride_order(&self) -> [Dim; 4] {
        match self {
            KvLayout::Raw => [Dim::Kv, Dim::Block, Dim::Token, Dim::Header],
            KvLayout::PageFriendly => [Dim::Block, Dim::Kv, Dim::Token, Dim::Header],
            KvLayout::HeaderCentric => [Dim::Block, Dim::Header, Dim::Kv, Dim::Token],
        }
    }

    /// Linear element offset of (block, kv, token, header) under this
    /// layout. `kv` is 0 for K, 1 for V. Offsets are in units of one
    /// head-element (multiply by `head_elem_bytes` for bytes).
    pub fn linear_offset(
        &self,
        g: &KvGeometry,
        block: u64,
        kv: u64,
        token: u64,
        header: u64,
    ) -> u64 {
        debug_assert!(block < g.num_blocks && kv < 2);
        debug_assert!(token < g.tokens_per_block && header < g.num_heads);
        let (b, t, h) = (g.num_blocks, g.tokens_per_block, g.num_heads);
        let _ = b;
        match self {
            KvLayout::Raw => ((kv * g.num_blocks + block) * t + token) * h + header,
            KvLayout::PageFriendly => ((block * 2 + kv) * t + token) * h + header,
            KvLayout::HeaderCentric => ((block * h + header) * 2 + kv) * t + token,
        }
    }

    /// Number of existing *pages* that must be shifted (copied or
    /// remapped) when appending one new block of KV at the end.
    ///
    /// Raw keeps K and V each globally contiguous, so growing the block
    /// region displaces everything after the K-region boundary —
    /// O(#pages). The block-major layouts append in place.
    pub fn shift_ops_on_append(&self, existing_pages: u64) -> u64 {
        match self {
            KvLayout::Raw => existing_pages,
            KvLayout::PageFriendly | KvLayout::HeaderCentric => 0,
        }
    }

    /// Number of contiguous byte-segments per block occupied by ONE head's
    /// K+V data. Migration moves heads between workers, so this is the
    /// scatter/gather granularity: 1 ⇒ a head's data is one contiguous
    /// span (in-place migration possible).
    pub fn segments_per_head_per_block(&self, g: &KvGeometry) -> u64 {
        match self {
            // token-major inside the block: each (kv, token) row holds one
            // element of this head → 2 × tokens_per_block scattered pieces.
            KvLayout::Raw | KvLayout::PageFriendly => 2 * g.tokens_per_block,
            // head-major: K and V of the head are adjacent → one span.
            KvLayout::HeaderCentric => 1,
        }
    }

    /// Copy operations required to *trim* (compact) one block after a
    /// scale-up migration removed `heads_removed` of `g.num_heads` heads.
    ///
    /// Header-centric keeps the retained heads contiguous, so the freed
    /// space is a single span that can be reused directly: O(1), and when
    /// the retained range starts at offset 0 (worker keeps its own shard
    /// in place) zero copies are needed. Token-major layouts interleave
    /// retained and freed data per token: O(#tokens-in-block) copies.
    pub fn trim_copies_per_block(&self, g: &KvGeometry, heads_removed: u64) -> u64 {
        if heads_removed == 0 {
            return 0;
        }
        match self {
            KvLayout::Raw | KvLayout::PageFriendly => 2 * g.tokens_per_block,
            KvLayout::HeaderCentric => 0,
        }
    }

    /// Human-readable hierarchy string (Table 2).
    pub fn hierarchy(&self) -> &'static str {
        match self {
            KvLayout::Raw => "[K/V, Block, Token, Header]",
            KvLayout::PageFriendly => "[Block, K/V, Token, Header]",
            KvLayout::HeaderCentric => "[Block, Header, K/V, Token]",
        }
    }
}

/// The permutation mapping a layout's storage order back to the attention
/// kernel's expected [Block, Kv, Token, Header] view — the
/// `kv_stride_order()` of §4.1.1. Returns, for each kernel-view dimension,
/// which storage dimension supplies it.
pub fn kv_stride_order(layout: KvLayout) -> [usize; 4] {
    // kernel view order:          [Block, Kv, Token, Header]
    let view = [Dim::Block, Dim::Kv, Dim::Token, Dim::Header];
    let storage = layout.stride_order();
    let mut out = [0usize; 4];
    for (i, d) in view.iter().enumerate() {
        out[i] = storage.iter().position(|s| s == d).unwrap();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geo() -> KvGeometry {
        KvGeometry { num_blocks: 4, tokens_per_block: 16, num_heads: 8, head_elem_bytes: 256 }
    }

    /// Every layout must be a bijection over the index space.
    #[test]
    fn offsets_are_bijective() {
        let g = geo();
        let n = (2 * g.num_blocks * g.tokens_per_block * g.num_heads) as usize;
        for layout in [KvLayout::Raw, KvLayout::PageFriendly, KvLayout::HeaderCentric] {
            let mut seen = vec![false; n];
            for b in 0..g.num_blocks {
                for kv in 0..2 {
                    for t in 0..g.tokens_per_block {
                        for h in 0..g.num_heads {
                            let off = layout.linear_offset(&g, b, kv, t, h) as usize;
                            assert!(off < n, "{layout:?} out of range");
                            assert!(!seen[off], "{layout:?} collision at {off}");
                            seen[off] = true;
                        }
                    }
                }
            }
            assert!(seen.iter().all(|&s| s), "{layout:?} not surjective");
        }
    }

    /// Header-centric: one head's K+V within a block is a contiguous span.
    #[test]
    fn header_centric_head_span_contiguous() {
        let g = geo();
        let l = KvLayout::HeaderCentric;
        for b in 0..g.num_blocks {
            for h in 0..g.num_heads {
                let mut offs: Vec<u64> = Vec::new();
                for kv in 0..2 {
                    for t in 0..g.tokens_per_block {
                        offs.push(l.linear_offset(&g, b, kv, t, h));
                    }
                }
                offs.sort_unstable();
                let span = offs[offs.len() - 1] - offs[0] + 1;
                assert_eq!(span as usize, offs.len(), "head {h} not contiguous");
            }
        }
    }

    /// Token-major layouts scatter a head across the block.
    #[test]
    fn page_friendly_head_span_scattered() {
        let g = geo();
        let l = KvLayout::PageFriendly;
        let mut offs: Vec<u64> = Vec::new();
        for kv in 0..2 {
            for t in 0..g.tokens_per_block {
                offs.push(l.linear_offset(&g, 0, kv, t, 3));
            }
        }
        offs.sort_unstable();
        let span = offs[offs.len() - 1] - offs[0] + 1;
        assert!(span as usize > offs.len(), "expected holes");
    }

    /// Blocks must be self-contained (block-major) for the page-friendly
    /// and header-centric layouts, but NOT for Raw.
    #[test]
    fn block_locality() {
        let g = geo();
        let block_elems = 2 * g.tokens_per_block * g.num_heads;
        for layout in [KvLayout::PageFriendly, KvLayout::HeaderCentric] {
            for b in 0..g.num_blocks {
                for kv in 0..2 {
                    for t in 0..g.tokens_per_block {
                        for h in 0..g.num_heads {
                            let off = layout.linear_offset(&g, b, kv, t, h);
                            assert_eq!(off / block_elems, b, "{layout:?}");
                        }
                    }
                }
            }
        }
        // Raw: V of block 0 lives in the second half — not block-local.
        let off = KvLayout::Raw.linear_offset(&g, 0, 1, 0, 0);
        assert_ne!(off / block_elems, 0);
    }

    #[test]
    fn table2_shift_and_trim_complexity() {
        let g = geo();
        // O(#pages) → 0
        assert_eq!(KvLayout::Raw.shift_ops_on_append(1000), 1000);
        assert_eq!(KvLayout::PageFriendly.shift_ops_on_append(1000), 0);
        assert_eq!(KvLayout::HeaderCentric.shift_ops_on_append(1000), 0);
        // O(#tokens) → O(1)
        assert_eq!(KvLayout::PageFriendly.trim_copies_per_block(&g, 6), 2 * g.tokens_per_block);
        assert_eq!(KvLayout::HeaderCentric.trim_copies_per_block(&g, 6), 0);
        assert_eq!(KvLayout::HeaderCentric.trim_copies_per_block(&g, 0), 0);
    }

    #[test]
    fn stride_order_permutations() {
        // PageFriendly storage == kernel view → identity permutation.
        assert_eq!(kv_stride_order(KvLayout::PageFriendly), [0, 1, 2, 3]);
        // HeaderCentric: [Block, Header, K/V, Token] → view picks 0,2,3,1.
        assert_eq!(kv_stride_order(KvLayout::HeaderCentric), [0, 2, 3, 1]);
        // Raw: [K/V, Block, Token, Header] → view picks 1,0,2,3.
        assert_eq!(kv_stride_order(KvLayout::Raw), [1, 0, 2, 3]);
    }

    #[test]
    fn geometry_byte_math() {
        let g = geo();
        assert_eq!(g.block_bytes(), 2 * 16 * 8 * 256);
        assert_eq!(g.total_bytes(), 4 * g.block_bytes());
        assert_eq!(g.head_bytes_per_block() * g.num_heads, g.block_bytes());
    }
}
