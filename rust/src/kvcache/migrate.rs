//! KV-cache migration during parallelism transformation (§4.1.2).
//!
//! Three strategies over the same migration volume (scale-up
//! `n×(TP1) → TPn`: every worker keeps its own head shard of local tokens
//! and exchanges the rest all-to-all):
//!
//! * **Basic** — single-shot all-to-all into freshly reserved pages, then
//!   *trim*: token-granular compaction copies of every local block
//!   (token-major layout leaves retained heads interleaved with holes).
//! * **Gyges⁻** — header-centric layout: retained heads are contiguous, no
//!   trim; *phased* all-to-all reuses pages freed by earlier stages, so
//!   peak extra memory is one stage's volume (+ metadata).
//! * **Gyges** — Gyges⁻ plus overlapping: driver calls run concurrently
//!   with compute and the all-to-all launches on an independent stream
//!   that consumes only spare SMs.
//!
//! Transformation is layer-by-layer (§4.3), so costs are reported per
//! layer: **wall** (Figure 9a-style transformation time) and **visible**
//! (what a serving step absorbs — Figure 11's currency), plus per-layer
//! peak extra memory (Figure 9b).

use super::layout::KvLayout;
use super::manager::KvManager;
use crate::config::{GpuSpec, ModelConfig};
use crate::sim::clock::SimDuration;
use crate::sim::comm::CommModel;
use crate::sim::link::Link;
use crate::sim::vmm::VmmCosts;

/// Migration strategy under comparison (Figure 9 series).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvMigrationStrategy {
    Basic,
    GygesNoOverlap,
    Gyges,
}

impl KvMigrationStrategy {
    pub fn name(&self) -> &'static str {
        match self {
            KvMigrationStrategy::Basic => "basic",
            KvMigrationStrategy::GygesNoOverlap => "gyges-",
            KvMigrationStrategy::Gyges => "gyges",
        }
    }

    pub fn layout(&self) -> KvLayout {
        match self {
            KvMigrationStrategy::Basic => KvLayout::PageFriendly,
            _ => KvLayout::HeaderCentric,
        }
    }
}

/// Calibration constants (DESIGN.md §5), fit against §6.2.1:
/// Basic extra time 3.15–4 ms/layer; Gyges⁻ ≈61% lower; Gyges ≈86% lower;
/// Gyges peak extra memory < 70 MB; header-centric −91.6% memory.
mod cal {
    /// Device-side scatter/gather launch latency per segment (µs) during
    /// trim compaction (batched copy kernel, not a driver call each).
    pub const TRIM_SEG_LATENCY_US: f64 = 0.02;
    /// Share of the all-to-all that stays visible for Gyges⁻ (phased but
    /// not stream-overlapped: stage syncs interleave with steps).
    pub const PHASED_VISIBLE_SHARE: f64 = 0.15;
    /// SM-busy share during decode — the only part of the overlapped
    /// all-to-all that contends with serving kernels (Gyges).
    pub const OVERLAP_VISIBLE_SHARE: f64 = 0.05;
    /// Default per-stage volume cap for phased migration.
    pub const STAGE_BYTES: u64 = 32 * 1024 * 1024;
}

/// Parameters of one KV transformation experiment.
#[derive(Clone, Debug)]
pub struct KvMigrationSpec {
    pub model: ModelConfig,
    pub gpu: GpuSpec,
    /// Source worker count (e.g. 4 TP1 instances merging).
    pub workers: u32,
    /// Target TP degree (== workers for the canonical 4×TP1→TP4).
    pub target_tp: u64,
    /// KV-pool utilization at transformation time (paper uses 0.9).
    pub kv_util: f64,
    /// SMs granted to migration copy kernels.
    pub sms: u32,
    /// Per-stage volume cap for phased migration (bytes).
    pub stage_bytes: u64,
}

impl KvMigrationSpec {
    /// The paper's canonical microbenchmark setting (§6.2.1).
    pub fn paper_default(model: ModelConfig) -> KvMigrationSpec {
        let gpu = GpuSpec::for_model(&model);
        KvMigrationSpec {
            model,
            gpu,
            workers: 4,
            target_tp: 4,
            kv_util: 0.9,
            sms: 78,
            stage_bytes: cal::STAGE_BYTES,
        }
    }

    /// Per-worker KV capacity in bytes (all layers) before transformation.
    pub fn worker_kv_bytes(&self) -> u64 {
        let e = crate::sim::EngineModel::new(self.model.clone(), self.gpu.clone());
        e.kv_capacity_bytes(1)
    }

    /// Per-worker KV bytes actually occupied (utilization applied).
    pub fn local_kv_bytes(&self) -> u64 {
        (self.worker_kv_bytes() as f64 * self.kv_util) as u64
    }

    /// Bytes each worker sends (it keeps its own 1/tp head shard).
    pub fn sent_bytes_per_worker(&self) -> u64 {
        self.local_kv_bytes() * (self.target_tp - 1) / self.target_tp
    }
}

/// Outcome of one simulated KV transformation.
#[derive(Clone, Debug)]
pub struct KvMigrationReport {
    pub strategy: KvMigrationStrategy,
    /// Wall time per layer.
    pub per_layer_wall: SimDuration,
    /// Serving-visible extra time per layer (Figure 9a's quantity).
    pub per_layer_visible: SimDuration,
    /// Peak extra device memory while one layer transforms (Figure 9b).
    pub per_layer_peak_bytes: u64,
    /// All-to-all bytes sent per worker (whole model).
    pub a2a_bytes: u64,
    /// Bytes copied on-device for trimming (whole model).
    pub trim_copy_bytes: u64,
    /// Number of all-to-all stages per layer.
    pub stages: u32,
}

impl KvMigrationReport {
    /// Whole-model wall time.
    pub fn total_wall(&self, layers: u64) -> SimDuration {
        SimDuration(self.per_layer_wall.0 * layers)
    }

    /// Whole-model serving-visible time.
    pub fn total_visible(&self, layers: u64) -> SimDuration {
        SimDuration(self.per_layer_visible.0 * layers)
    }
}

/// Simulate one KV transformation under `strategy`.
pub fn run_kv_migration(
    spec: &KvMigrationSpec,
    strategy: KvMigrationStrategy,
) -> KvMigrationReport {
    let comm = CommModel::for_gpu(&spec.gpu);
    let vmm = VmmCosts::default();
    let layers = spec.model.num_layers;
    let sent_total = spec.sent_bytes_per_worker();
    let sent_layer = sent_total / layers;
    let local_layer = spec.local_kv_bytes() / layers;
    let kept_layer = local_layer - sent_layer;

    // Per-layer mechanics on a real page pool.
    let layer_pool = spec.worker_kv_bytes() / layers;
    let mut mgr = KvManager::new(&spec.model, 1, strategy.layout(), layer_pool);
    mgr.fill_to(spec.kv_util, 2048, 1);
    let geo = mgr.geometry();
    let local_blocks = mgr.tables.total_blocks();
    let heads_removed = geo.num_heads - geo.num_heads / spec.target_tp;

    // Per-layer all-to-all wall time.
    let a2a_layer = comm.all_to_all(spec.workers, sent_layer, spec.sms);

    match strategy {
        KvMigrationStrategy::Basic => {
            // Trim: token-granular compaction copies of every local block.
            let copies_per_block =
                strategy.layout().trim_copies_per_block(&geo, heads_removed);
            let total_copies = copies_per_block * local_blocks;
            let seg_bytes = geo.head_elem_bytes * (geo.num_heads - heads_removed);
            let scatter = Link { alpha_us: cal::TRIM_SEG_LATENCY_US, bw: spec.gpu.hbm_bw };
            let trim = scatter.transfer_time_n(total_copies, seg_bytes);
            // Freed pages unmapped in one batched driver call per layer.
            let driver = vmm.op_time(local_blocks.max(1));
            // Received bytes land in NEW pages before any local page can be
            // freed (holes until trim), plus the compacted copy of kept KV.
            let peak = sent_layer + kept_layer;
            KvMigrationReport {
                strategy,
                per_layer_wall: a2a_layer + trim + driver,
                per_layer_visible: trim + driver,
                per_layer_peak_bytes: peak,
                a2a_bytes: sent_total,
                trim_copy_bytes: total_copies * seg_bytes * layers,
                stages: 1,
            }
        }
        KvMigrationStrategy::GygesNoOverlap | KvMigrationStrategy::Gyges => {
            // Phased: stage k frees its pages for stage k+1's landing zone.
            let stages = (sent_layer.div_ceil(spec.stage_bytes)).max(1) as u32;
            let a2a_phased =
                comm.all_to_all_phased(spec.workers, sent_layer, spec.sms, stages);
            let meta_bytes = 4096u64 * stages as u64;
            let peak = spec.stage_bytes.min(sent_layer.max(1)) + meta_bytes;
            // Batched remap per stage — each stage remaps only the blocks
            // it freed (header-centric: freed head segments are contiguous
            // → block reshaping is metadata only).
            let blocks_per_stage = (local_blocks / stages as u64).max(1);
            let driver = vmm.op_time_calls(stages as u64, blocks_per_stage);
            let (wall, visible) = if strategy == KvMigrationStrategy::Gyges {
                (
                    a2a_phased,
                    a2a_layer.scale(cal::OVERLAP_VISIBLE_SHARE),
                )
            } else {
                (
                    a2a_phased + driver,
                    a2a_layer.scale(cal::PHASED_VISIBLE_SHARE) + driver,
                )
            };
            KvMigrationReport {
                strategy,
                per_layer_wall: wall,
                per_layer_visible: visible,
                per_layer_peak_bytes: peak,
                a2a_bytes: sent_total,
                trim_copy_bytes: 0,
                stages,
            }
        }
    }
}

/// Run all three strategies (Figure 9 rows) for one model.
pub fn fig9_series(model: ModelConfig) -> Vec<KvMigrationReport> {
    let spec = KvMigrationSpec::paper_default(model);
    [
        KvMigrationStrategy::Basic,
        KvMigrationStrategy::GygesNoOverlap,
        KvMigrationStrategy::Gyges,
    ]
    .into_iter()
    .map(|s| run_kv_migration(&spec, s))
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> KvMigrationSpec {
        KvMigrationSpec::paper_default(ModelConfig::qwen2_5_32b())
    }

    #[test]
    fn volumes_are_consistent() {
        let s = spec();
        assert_eq!(s.sent_bytes_per_worker(), s.local_kv_bytes() * 3 / 4);
        assert!(s.local_kv_bytes() < s.worker_kv_bytes());
    }

    #[test]
    fn whole_model_a2a_wall_near_paper_anchor() {
        // The 4×TP1→TP4 full-KV move at 78 SMs anchors to 522 ms (§3.4).
        let s = spec();
        let r = run_kv_migration(&s, KvMigrationStrategy::GygesNoOverlap);
        let wall_s = r.total_wall(s.model.num_layers).as_secs_f64();
        assert!((0.40..0.75).contains(&wall_s), "wall {wall_s}s");
    }

    #[test]
    fn basic_visible_in_paper_band() {
        // §6.2.1: Basic adds 3.15–4 ms per layer across the paper's
        // models. Our mechanistic trim model spreads wider across
        // architectures (MHA llama2 has 4× the KV of the GQA models);
        // the Qwen anchor must stay in-band, others within 0.5–12 ms.
        for m in ModelConfig::eval_set() {
            let s = KvMigrationSpec::paper_default(m.clone());
            let r = run_kv_migration(&s, KvMigrationStrategy::Basic);
            let ms = r.per_layer_visible.as_millis_f64();
            assert!((0.5..12.0).contains(&ms), "{}: basic visible {ms} ms", m.name);
        }
        let s = KvMigrationSpec::paper_default(ModelConfig::qwen2_5_32b());
        let r = run_kv_migration(&s, KvMigrationStrategy::Basic);
        let ms = r.per_layer_visible.as_millis_f64();
        assert!((1.5..6.0).contains(&ms), "qwen anchor {ms} ms");
    }

    #[test]
    fn gyges_minus_saving_near_61pct() {
        let s = spec();
        let basic = run_kv_migration(&s, KvMigrationStrategy::Basic);
        let minus = run_kv_migration(&s, KvMigrationStrategy::GygesNoOverlap);
        let saving = 1.0
            - minus.per_layer_visible.as_secs_f64() / basic.per_layer_visible.as_secs_f64();
        assert!((0.40..0.80).contains(&saving), "saving {saving}");
        assert_eq!(minus.trim_copy_bytes, 0);
        assert!(basic.trim_copy_bytes > 0);
    }

    #[test]
    fn gyges_saving_near_86pct() {
        let s = spec();
        let basic = run_kv_migration(&s, KvMigrationStrategy::Basic);
        let full = run_kv_migration(&s, KvMigrationStrategy::Gyges);
        let saving = 1.0
            - full.per_layer_visible.as_secs_f64() / basic.per_layer_visible.as_secs_f64();
        assert!((0.75..0.97).contains(&saving), "saving {saving}");
    }

    #[test]
    fn gyges_peak_memory_below_70mb() {
        let s = spec();
        let full = run_kv_migration(&s, KvMigrationStrategy::Gyges);
        assert!(
            full.per_layer_peak_bytes
                < crate::config::calib::transform::GYGES_PEAK_EXTRA_BYTES,
            "peak {}",
            crate::util::fmt_bytes(full.per_layer_peak_bytes)
        );
        // Header-centric phased migration saves ~91.6% memory vs Basic.
        let basic = run_kv_migration(&s, KvMigrationStrategy::Basic);
        let saving =
            1.0 - full.per_layer_peak_bytes as f64 / basic.per_layer_peak_bytes as f64;
        assert!((0.80..0.99).contains(&saving), "memory saving {saving}");
    }

    #[test]
    fn series_runs_for_all_eval_models() {
        for m in ModelConfig::eval_set() {
            let series = fig9_series(m.clone());
            assert_eq!(series.len(), 3);
            for r in &series {
                assert!(r.per_layer_wall.0 > 0, "{}: zero wall", m.name);
            }
        }
    }

    #[test]
    fn fewer_sms_slow_the_move() {
        let mut s = spec();
        let fast = run_kv_migration(&s, KvMigrationStrategy::GygesNoOverlap);
        s.sms = 1;
        let slow = run_kv_migration(&s, KvMigrationStrategy::GygesNoOverlap);
        assert!(
            slow.per_layer_wall.as_secs_f64() > 2.0 * fast.per_layer_wall.as_secs_f64()
        );
    }

    #[test]
    fn totals_scale_with_layers() {
        let s = spec();
        let r = run_kv_migration(&s, KvMigrationStrategy::Gyges);
        assert_eq!(
            r.total_visible(10).0,
            r.per_layer_visible.0 * 10
        );
    }
}
