//! Per-worker KV-cache manager: page-granular allocation of block tables
//! under a chosen layout.
//!
//! The manager models ONE representative transformer layer (all layers are
//! symmetric; totals multiply by `num_layers`), keeping per-block realism
//! tractable: one KV block occupies exactly one 2 MiB VMM page, matching
//! vAttention-style page-per-layer management.

use super::block_table::{BlockTable, BlockTableSet, RequestId};
use super::layout::{KvGeometry, KvLayout};
use crate::config::ModelConfig;
use crate::sim::vmm::{PagePool, VmmError};
use crate::util::bytes::VMM_PAGE;

/// KV-cache manager for one worker (one layer's pool; symmetric layers).
#[derive(Clone, Debug)]
pub struct KvManager {
    pub layout: KvLayout,
    pub pool: PagePool,
    pub tables: BlockTableSet,
    /// Tokens that fit in one block (= one VMM page) of this layer.
    pub tokens_per_block: u64,
    /// KV bytes per token for this layer (all local heads).
    pub kv_bytes_per_token: u64,
    pub num_heads: u64,
    pub head_elem_bytes: u64,
    /// Count of shift operations incurred by appends (Raw layout only).
    pub shift_ops: u64,
}

impl KvManager {
    /// Build a manager for `model` at TP degree `tp` with `layer_pool_bytes`
    /// of device memory dedicated to this layer's KV.
    pub fn new(model: &ModelConfig, tp: u64, layout: KvLayout, layer_pool_bytes: u64) -> KvManager {
        let local_heads = (model.num_kv_heads / tp).max(1);
        let kv_bytes_per_token = 2 * local_heads * model.head_dim * model.dtype_bytes;
        let tokens_per_block = (VMM_PAGE / kv_bytes_per_token).max(1);
        KvManager {
            layout,
            pool: PagePool::new(layer_pool_bytes),
            tables: BlockTableSet::default(),
            tokens_per_block,
            kv_bytes_per_token,
            num_heads: local_heads,
            head_elem_bytes: model.head_dim * model.dtype_bytes,
            shift_ops: 0,
        }
    }

    /// Geometry handle for layout math.
    pub fn geometry(&self) -> KvGeometry {
        KvGeometry {
            num_blocks: self.pool.total_pages(),
            tokens_per_block: self.tokens_per_block,
            num_heads: self.num_heads,
            head_elem_bytes: self.head_elem_bytes,
        }
    }

    /// Admit a new request with `tokens` of prefill KV.
    pub fn admit(&mut self, req: RequestId, tokens: u64) -> Result<(), VmmError> {
        let mut table = BlockTable::new(self.tokens_per_block);
        let need = table.blocks_to_grow(tokens);
        let pages = self.pool.alloc(need)?;
        self.shift_ops += self.layout.shift_ops_on_append(self.pool.allocated_pages());
        table.extend(pages, tokens);
        self.tables.insert(req, table);
        Ok(())
    }

    /// Append `tokens` decode tokens to an existing request.
    pub fn append(&mut self, req: RequestId, tokens: u64) -> Result<(), VmmError> {
        // Count shifts before borrowing the table mutably.
        let allocated = self.pool.allocated_pages();
        let table = self.tables.get_mut(req).ok_or(VmmError::NotAllocated(req))?;
        let need = table.blocks_to_grow(tokens);
        if need > 0 {
            let pages = self.pool.alloc(need)?;
            self.shift_ops += self.layout.shift_ops_on_append(allocated);
            table.extend(pages, tokens);
        } else {
            table.extend(Vec::new(), tokens);
        }
        Ok(())
    }

    /// Release a finished request's blocks.
    pub fn finish(&mut self, req: RequestId) -> Result<(), VmmError> {
        let table = self.tables.remove(req).ok_or(VmmError::NotAllocated(req))?;
        self.pool.release(&table.blocks)
    }

    /// Fraction of the pool currently allocated.
    pub fn utilization(&self) -> f64 {
        if self.pool.total_pages() == 0 {
            return 0.0;
        }
        self.pool.allocated_pages() as f64 / self.pool.total_pages() as f64
    }

    /// Total KV bytes stored (token-exact, ignoring tail slack).
    pub fn stored_bytes(&self) -> u64 {
        self.tables.total_tokens() * self.kv_bytes_per_token
    }

    /// Bytes occupied including tail slack (page-granular truth).
    pub fn occupied_bytes(&self) -> u64 {
        self.tables.total_blocks() * VMM_PAGE
    }

    /// Fill the pool to approximately `util` utilization with synthetic
    /// requests of `req_tokens` tokens each (bench/experiment helper).
    pub fn fill_to(&mut self, util: f64, req_tokens: u64, first_id: RequestId) -> Vec<RequestId> {
        let mut ids = Vec::new();
        let target = (self.pool.total_pages() as f64 * util) as u64;
        let mut next = first_id;
        while self.pool.allocated_pages() < target {
            let remaining_pages = target - self.pool.allocated_pages();
            let tokens = req_tokens.min(remaining_pages * self.tokens_per_block);
            if tokens == 0 || self.admit(next, tokens).is_err() {
                break;
            }
            ids.push(next);
            next += 1;
        }
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::MIB;

    fn mk(layout: KvLayout) -> KvManager {
        KvManager::new(&ModelConfig::qwen2_5_32b(), 1, layout, 64 * MIB)
    }

    #[test]
    fn tokens_per_block_matches_page() {
        let m = mk(KvLayout::HeaderCentric);
        // Qwen TP1: 2×8 heads×128 dim×2 B = 4096 B/token/layer → 512 tok/page
        assert_eq!(m.kv_bytes_per_token, 4096);
        assert_eq!(m.tokens_per_block, 512);
    }

    #[test]
    fn admit_append_finish_accounting() {
        let mut m = mk(KvLayout::HeaderCentric);
        m.admit(1, 700).unwrap(); // 2 blocks
        assert_eq!(m.pool.allocated_pages(), 2);
        m.append(1, 300).unwrap(); // 1000 tokens → still 2 blocks
        assert_eq!(m.pool.allocated_pages(), 2);
        m.append(1, 100).unwrap(); // 1100 → 3 blocks
        assert_eq!(m.pool.allocated_pages(), 3);
        m.finish(1).unwrap();
        assert_eq!(m.pool.allocated_pages(), 0);
        assert_eq!(m.shift_ops, 0); // header-centric never shifts
    }

    #[test]
    fn raw_layout_accumulates_shift_ops() {
        let mut m = mk(KvLayout::Raw);
        m.admit(1, 512).unwrap();
        m.append(1, 512).unwrap();
        m.append(1, 512).unwrap();
        assert!(m.shift_ops > 0, "raw layout must shift on growth");
    }

    #[test]
    fn fill_to_reaches_target() {
        let mut m = mk(KvLayout::HeaderCentric);
        let ids = m.fill_to(0.9, 600, 100);
        assert!(!ids.is_empty());
        assert!((m.utilization() - 0.9).abs() < 0.1, "util {}", m.utilization());
    }

    #[test]
    fn oom_on_overfill() {
        let mut m = mk(KvLayout::HeaderCentric);
        let cap_tokens = m.pool.total_pages() * m.tokens_per_block;
        assert!(m.admit(1, cap_tokens + 1).is_err());
    }

    #[test]
    fn stored_vs_occupied() {
        let mut m = mk(KvLayout::HeaderCentric);
        m.admit(1, 10).unwrap(); // tiny request, one full page occupied
        assert_eq!(m.stored_bytes(), 10 * 4096);
        assert_eq!(m.occupied_bytes(), 2 * MIB);
    }
}
