//! KV-cache subsystem (paper §4.1): layouts, block tables, the per-worker
//! page-granular manager, and the migration strategies compared in
//! Figure 9 / Table 2.

pub mod block_table;
pub mod layout;
pub mod manager;
pub mod migrate;

pub use block_table::{BlockId, BlockTable, BlockTableSet, RequestId};
pub use layout::{kv_stride_order, Dim, KvGeometry, KvLayout};
pub use manager::KvManager;
pub use migrate::{
    fig9_series, run_kv_migration, KvMigrationReport, KvMigrationSpec, KvMigrationStrategy,
};
