//! Per-request block tables: map a request's logical token range onto
//! physical KV blocks, vLLM-style.

use std::collections::BTreeMap;

/// Identifier of a physical KV block on a worker.
pub type BlockId = u64;

/// Identifier of a request.
pub type RequestId = u64;

/// The block table of one request on one worker.
#[derive(Clone, Debug, Default)]
pub struct BlockTable {
    /// Physical blocks in logical order.
    pub blocks: Vec<BlockId>,
    /// Tokens stored (may leave the last block partially filled).
    pub num_tokens: u64,
    pub tokens_per_block: u64,
}

impl BlockTable {
    pub fn new(tokens_per_block: u64) -> BlockTable {
        BlockTable { blocks: Vec::new(), num_tokens: 0, tokens_per_block }
    }

    /// Blocks needed to store `tokens` tokens.
    pub fn blocks_needed(tokens: u64, tokens_per_block: u64) -> u64 {
        tokens.div_ceil(tokens_per_block)
    }

    /// How many new blocks must be appended to accommodate `extra` tokens.
    pub fn blocks_to_grow(&self, extra: u64) -> u64 {
        let need = Self::blocks_needed(self.num_tokens + extra, self.tokens_per_block);
        need.saturating_sub(self.blocks.len() as u64)
    }

    /// Record appended blocks + tokens.
    pub fn extend(&mut self, new_blocks: Vec<BlockId>, tokens: u64) {
        self.blocks.extend(new_blocks);
        self.num_tokens += tokens;
        debug_assert!(
            Self::blocks_needed(self.num_tokens, self.tokens_per_block)
                <= self.blocks.len() as u64,
            "block table under-provisioned"
        );
    }

    /// Physical block + in-block offset of a logical token index.
    pub fn locate(&self, token: u64) -> Option<(BlockId, u64)> {
        if token >= self.num_tokens {
            return None;
        }
        let b = (token / self.tokens_per_block) as usize;
        Some((self.blocks[b], token % self.tokens_per_block))
    }

    /// Free slots in the last block.
    pub fn tail_slack(&self) -> u64 {
        let cap = self.blocks.len() as u64 * self.tokens_per_block;
        cap - self.num_tokens
    }
}

/// All block tables of a worker, by request.
#[derive(Clone, Debug, Default)]
pub struct BlockTableSet {
    tables: BTreeMap<RequestId, BlockTable>,
}

impl BlockTableSet {
    pub fn get(&self, req: RequestId) -> Option<&BlockTable> {
        self.tables.get(&req)
    }

    pub fn get_mut(&mut self, req: RequestId) -> Option<&mut BlockTable> {
        self.tables.get_mut(&req)
    }

    pub fn insert(&mut self, req: RequestId, table: BlockTable) {
        self.tables.insert(req, table);
    }

    pub fn remove(&mut self, req: RequestId) -> Option<BlockTable> {
        self.tables.remove(&req)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&RequestId, &BlockTable)> {
        self.tables.iter()
    }

    pub fn len(&self) -> usize {
        self.tables.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tables.is_empty()
    }

    /// Total tokens stored across requests.
    pub fn total_tokens(&self) -> u64 {
        self.tables.values().map(|t| t.num_tokens).sum()
    }

    /// Total physical blocks referenced.
    pub fn total_blocks(&self) -> u64 {
        self.tables.values().map(|t| t.blocks.len() as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grow_math() {
        let mut t = BlockTable::new(16);
        assert_eq!(t.blocks_to_grow(1), 1);
        t.extend(vec![7], 10);
        assert_eq!(t.blocks_to_grow(6), 0); // fits in slack
        assert_eq!(t.tail_slack(), 6);
        assert_eq!(t.blocks_to_grow(7), 1);
        t.extend(vec![9], 7);
        assert_eq!(t.num_tokens, 17);
        assert_eq!(t.blocks, vec![7, 9]);
    }

    #[test]
    fn locate_tokens() {
        let mut t = BlockTable::new(4);
        t.extend(vec![100, 200], 6);
        assert_eq!(t.locate(0), Some((100, 0)));
        assert_eq!(t.locate(3), Some((100, 3)));
        assert_eq!(t.locate(4), Some((200, 0)));
        assert_eq!(t.locate(5), Some((200, 1)));
        assert_eq!(t.locate(6), None);
    }

    #[test]
    fn set_accounting() {
        let mut s = BlockTableSet::default();
        let mut a = BlockTable::new(4);
        a.extend(vec![1, 2], 8);
        let mut b = BlockTable::new(4);
        b.extend(vec![3], 2);
        s.insert(10, a);
        s.insert(11, b);
        assert_eq!(s.total_tokens(), 10);
        assert_eq!(s.total_blocks(), 3);
        s.remove(10);
        assert_eq!(s.total_blocks(), 1);
    }

    #[test]
    #[should_panic(expected = "under-provisioned")]
    fn overflow_detected_in_debug() {
        let mut t = BlockTable::new(4);
        t.extend(vec![1], 9); // 9 tokens need 3 blocks, only 1 given
    }
}
