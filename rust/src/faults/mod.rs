//! Deterministic fault injection: seeded fault plans whose faults flow
//! through the simulator's [`crate::sim::EventQueue`] as first-class
//! events, so every determinism property of the simulator carries over —
//! same seed, same fault storm, byte-identical output, including across
//! the work-stealing sweep driver and snapshot kill/resume.
//!
//! Four failure modes, chosen to exercise exactly the machinery the paper
//! assumes never fails:
//! * **Host crash** — every instance on the host loses its KV cache and
//!   its in-flight requests; the host restarts after an MTTR and its GPUs
//!   rejoin as fresh TP1 instances.
//! * **Instance stall** — a transient hang (driver hiccup, network
//!   partition blip): the in-flight step is discarded and the instance
//!   freezes for the stall window, then resumes with its state intact.
//! * **Transform abort** — a mid-flight [`crate::transform::TransformExec`]
//!   fails and rolls back to `from_tp`, paying a charged rollback cost.
//! * **Link failure** — the host's interconnect drops: KV-migration
//!   transforms in flight abort, and no new transformation may target the
//!   host until the link restores.
//!
//! An empty plan injects nothing and pushes no events, so a zero-fault
//! run is byte-identical to a run without any plan at all (proven by
//! `tests/faults.rs`).

use crate::sim::clock::{SimDuration, SimTime};
use crate::util::json::Json;
use crate::util::prng::Prng;

/// One failure mode, with its target and (where applicable) duration.
///
/// Crash and link faults target a *host* (hosts are stable identities);
/// stall and abort faults target a *worker* GPU id (also stable), which is
/// resolved to whichever live instance owns that GPU when the fault fires
/// — instance ids churn across merges/splits and would make plans
/// meaningless as written.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Host loses all instances (KV caches gone, in-flight requests
    /// requeued); restarts after `mttr`.
    HostCrash { host: usize, mttr: SimDuration },
    /// The instance owning `worker` freezes for `dur`; its in-flight step
    /// is discarded but queued/running requests survive.
    InstanceStall { worker: usize, dur: SimDuration },
    /// The in-flight transformation on the instance owning `worker`
    /// aborts and rolls back to `from_tp` with a charged rollback cost.
    TransformAbort { worker: usize },
    /// The host's interconnect drops for `dur`: in-flight KV-migration
    /// transforms on the host abort, and the host is excluded from new
    /// transformations until the link restores.
    LinkDown { host: usize, dur: SimDuration },
}

/// One scheduled fault.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fault {
    pub at: SimTime,
    pub kind: FaultKind,
}

/// A deterministic schedule of faults, sorted ascending by fire time.
///
/// The simulator keeps a cursor into the plan and at any moment has at
/// most ONE fault event outstanding in its queue (the next one); firing
/// it schedules the one after. This keeps the event-queue contents — and
/// therefore sequence numbering and output bytes — independent of how
/// many faults the plan holds beyond the cursor, and makes the plan
/// trivially snapshottable (plan + cursor).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    /// A plan that injects nothing (and pushes no events).
    pub fn empty() -> FaultPlan {
        FaultPlan { faults: Vec::new() }
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn len(&self) -> usize {
        self.faults.len()
    }

    /// Check the plan against a cluster shape: targets in range, sorted
    /// fire times, positive durations.
    pub fn validate(&self, hosts: usize, gpus_per_host: usize) -> Result<(), String> {
        let workers = hosts * gpus_per_host;
        let mut prev = SimTime::ZERO;
        for (i, f) in self.faults.iter().enumerate() {
            if f.at < prev {
                return Err(format!("fault {i}: fire times must ascend"));
            }
            prev = f.at;
            match f.kind {
                FaultKind::HostCrash { host, mttr } => {
                    if host >= hosts {
                        return Err(format!("fault {i}: host {host} out of range ({hosts})"));
                    }
                    if mttr == SimDuration::ZERO {
                        return Err(format!("fault {i}: mttr must be positive"));
                    }
                }
                FaultKind::LinkDown { host, dur } => {
                    if host >= hosts {
                        return Err(format!("fault {i}: host {host} out of range ({hosts})"));
                    }
                    if dur == SimDuration::ZERO {
                        return Err(format!("fault {i}: link outage must be positive"));
                    }
                }
                FaultKind::InstanceStall { worker, dur } => {
                    if worker >= workers {
                        return Err(format!("fault {i}: worker {worker} out of range ({workers})"));
                    }
                    if dur == SimDuration::ZERO {
                        return Err(format!("fault {i}: stall must be positive"));
                    }
                }
                FaultKind::TransformAbort { worker } => {
                    if worker >= workers {
                        return Err(format!("fault {i}: worker {worker} out of range ({workers})"));
                    }
                }
            }
        }
        Ok(())
    }

    /// Generate a seeded fault storm over `[0, horizon_s)`: Poisson fault
    /// arrivals at `intensity` faults/minute, with a fixed kind mix
    /// (crash 20%, stall 35%, abort 25%, link 20%) and exponential
    /// repair/stall/outage tails. Same seed → same storm, always.
    pub fn storm(
        seed: u64,
        horizon_s: f64,
        hosts: usize,
        gpus_per_host: usize,
        intensity: f64,
    ) -> FaultPlan {
        assert!(hosts > 0 && gpus_per_host > 0, "storm needs a cluster shape");
        assert!(intensity > 0.0 && horizon_s > 0.0, "storm needs a positive rate and horizon");
        let mut rng = Prng::new(seed);
        let rate_per_s = intensity / 60.0;
        let workers = hosts * gpus_per_host;
        let mut t = 0.0;
        let mut faults = Vec::new();
        loop {
            t += rng.exp(rate_per_s);
            if t >= horizon_s {
                break;
            }
            let at = SimTime::from_secs_f64(t);
            let roll = rng.f64();
            let kind = if roll < 0.20 {
                let host = rng.index(hosts);
                let mttr = SimDuration::from_secs_f64(5.0 + rng.exp(0.1));
                FaultKind::HostCrash { host, mttr }
            } else if roll < 0.55 {
                let worker = rng.index(workers);
                let dur = SimDuration::from_secs_f64(0.5 + rng.exp(0.5));
                FaultKind::InstanceStall { worker, dur }
            } else if roll < 0.80 {
                let worker = rng.index(workers);
                FaultKind::TransformAbort { worker }
            } else {
                let host = rng.index(hosts);
                let dur = SimDuration::from_secs_f64(2.0 + rng.exp(0.25));
                FaultKind::LinkDown { host, dur }
            };
            faults.push(Fault { at, kind });
        }
        FaultPlan { faults }
    }

    /// Serialize for snapshots and the chaos CLI.
    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .faults
            .iter()
            .map(|f| {
                let mut o = Json::obj();
                o.set("at", f.at.0);
                match f.kind {
                    FaultKind::HostCrash { host, mttr } => {
                        o.set("kind", "crash").set("host", host).set("dur", mttr.0);
                    }
                    FaultKind::InstanceStall { worker, dur } => {
                        o.set("kind", "stall").set("worker", worker).set("dur", dur.0);
                    }
                    FaultKind::TransformAbort { worker } => {
                        o.set("kind", "abort").set("worker", worker);
                    }
                    FaultKind::LinkDown { host, dur } => {
                        o.set("kind", "link").set("host", host).set("dur", dur.0);
                    }
                }
                o
            })
            .collect();
        let mut o = Json::obj();
        o.set("faults", Json::Arr(rows));
        o
    }

    /// Parse the [`Self::to_json`] form back.
    pub fn from_json(v: &Json) -> Result<FaultPlan, String> {
        let rows = v.req_arr("faults", "fault plan")?;
        let mut faults = Vec::with_capacity(rows.len());
        for (i, row) in rows.iter().enumerate() {
            let ctx = format!("fault {i}");
            let at = SimTime(row.req_u64("at", &ctx)?);
            let kind = match row.req_str("kind", &ctx)? {
                "crash" => FaultKind::HostCrash {
                    host: row.req_u64("host", &ctx)? as usize,
                    mttr: SimDuration(row.req_u64("dur", &ctx)?),
                },
                "stall" => FaultKind::InstanceStall {
                    worker: row.req_u64("worker", &ctx)? as usize,
                    dur: SimDuration(row.req_u64("dur", &ctx)?),
                },
                "abort" => FaultKind::TransformAbort {
                    worker: row.req_u64("worker", &ctx)? as usize,
                },
                "link" => FaultKind::LinkDown {
                    host: row.req_u64("host", &ctx)? as usize,
                    dur: SimDuration(row.req_u64("dur", &ctx)?),
                },
                other => return Err(format!("{ctx}: unknown kind {other:?}")),
            };
            faults.push(Fault { at, kind });
        }
        Ok(FaultPlan { faults })
    }

    /// Feed the plan's identity into a fingerprint hasher's byte stream
    /// (shard-manifest job hashing: a faulted job must never alias its
    /// unfaulted twin).
    pub fn fingerprint_into(&self, bytes: &mut Vec<u8>) {
        bytes.extend_from_slice(&(self.faults.len() as u64).to_le_bytes());
        for f in &self.faults {
            bytes.extend_from_slice(&f.at.0.to_le_bytes());
            match f.kind {
                FaultKind::HostCrash { host, mttr } => {
                    bytes.push(1);
                    bytes.extend_from_slice(&(host as u64).to_le_bytes());
                    bytes.extend_from_slice(&mttr.0.to_le_bytes());
                }
                FaultKind::InstanceStall { worker, dur } => {
                    bytes.push(2);
                    bytes.extend_from_slice(&(worker as u64).to_le_bytes());
                    bytes.extend_from_slice(&dur.0.to_le_bytes());
                }
                FaultKind::TransformAbort { worker } => {
                    bytes.push(3);
                    bytes.extend_from_slice(&(worker as u64).to_le_bytes());
                }
                FaultKind::LinkDown { host, dur } => {
                    bytes.push(4);
                    bytes.extend_from_slice(&(host as u64).to_le_bytes());
                    bytes.extend_from_slice(&dur.0.to_le_bytes());
                }
            }
        }
    }
}

/// Bounded retry with exponential backoff for requeued/deferred requests.
///
/// The defaults (`max_attempts == 0`, `backoff_base_s == 0.0`) reproduce
/// the pre-fault behaviour exactly: unlimited retries, no backoff — every
/// new branch in the coordinator is a no-op, keeping zero-fault runs
/// byte-identical. A bounded policy is the simulator's admission-control /
/// load-shedding mechanism: when capacity < demand, requests exhaust
/// their attempts and drop (counted) instead of livelocking the backlog.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    /// Attempts before a request is dropped; `0` means unlimited.
    pub max_attempts: u32,
    /// First-retry delay in seconds; doubles per attempt. `0` disables
    /// backoff (retries are immediately eligible).
    pub backoff_base_s: f64,
}

impl RetryPolicy {
    /// Unlimited retries, no backoff — the legacy behaviour.
    pub fn unlimited() -> RetryPolicy {
        RetryPolicy { max_attempts: 0, backoff_base_s: 0.0 }
    }

    /// Does this policy ever drop a request?
    pub fn bounded(&self) -> bool {
        self.max_attempts > 0
    }

    /// Has a request with `attempts` failed placements exhausted its
    /// budget?
    pub fn exhausted(&self, attempts: u32) -> bool {
        self.max_attempts > 0 && attempts >= self.max_attempts
    }

    /// Earliest time a request that just failed its `attempts`-th
    /// placement becomes eligible again: `now + base · 2^(attempts-1)`,
    /// exponent capped so the duration stays finite.
    pub fn next_retry(&self, now: SimTime, attempts: u32) -> SimTime {
        if self.backoff_base_s <= 0.0 || attempts == 0 {
            return now;
        }
        let exp = (attempts - 1).min(10);
        now + SimDuration::from_secs_f64(self.backoff_base_s * f64::from(1u32 << exp))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_valid_and_injects_nothing() {
        let p = FaultPlan::empty();
        assert!(p.is_empty());
        assert_eq!(p.len(), 0);
        p.validate(1, 8).unwrap();
    }

    #[test]
    fn storm_is_deterministic_and_valid() {
        let a = FaultPlan::storm(42, 120.0, 2, 8, 6.0);
        let b = FaultPlan::storm(42, 120.0, 2, 8, 6.0);
        assert_eq!(a, b, "same seed must give the same storm");
        assert!(!a.is_empty(), "2 min at 6 faults/min should fire");
        a.validate(2, 8).unwrap();
        let c = FaultPlan::storm(43, 120.0, 2, 8, 6.0);
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn storm_respects_horizon_and_ascends() {
        let p = FaultPlan::storm(7, 60.0, 1, 8, 12.0);
        let horizon = SimTime::from_secs_f64(60.0);
        let mut prev = SimTime::ZERO;
        for f in &p.faults {
            assert!(f.at < horizon);
            assert!(f.at >= prev);
            prev = f.at;
        }
    }

    #[test]
    fn json_roundtrip() {
        let p = FaultPlan::storm(99, 90.0, 2, 4, 8.0);
        let s = p.to_json().to_string();
        let back = FaultPlan::from_json(&Json::parse(&s).unwrap()).unwrap();
        assert_eq!(p, back);
        // Empty plan roundtrips too.
        let e = FaultPlan::empty();
        let back = FaultPlan::from_json(&Json::parse(&e.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(e, back);
    }

    #[test]
    fn from_json_rejects_malformed() {
        assert!(FaultPlan::from_json(&Json::parse(r#"{}"#).unwrap()).is_err());
        let bad = r#"{"faults":[{"at":5,"kind":"meteor"}]}"#;
        assert!(FaultPlan::from_json(&Json::parse(bad).unwrap()).is_err());
        let missing = r#"{"faults":[{"at":5,"kind":"crash","host":0}]}"#;
        assert!(FaultPlan::from_json(&Json::parse(missing).unwrap()).is_err());
    }

    #[test]
    fn validate_catches_bad_targets() {
        let p = FaultPlan {
            faults: vec![Fault {
                at: SimTime::ZERO,
                kind: FaultKind::HostCrash { host: 3, mttr: SimDuration::from_secs_f64(5.0) },
            }],
        };
        assert!(p.validate(2, 8).is_err());
        let p = FaultPlan {
            faults: vec![Fault {
                at: SimTime::ZERO,
                kind: FaultKind::InstanceStall { worker: 16, dur: SimDuration::from_secs_f64(1.0) },
            }],
        };
        assert!(p.validate(2, 8).is_err());
        let unsorted = FaultPlan {
            faults: vec![
                Fault {
                    at: SimTime::from_secs_f64(2.0),
                    kind: FaultKind::TransformAbort { worker: 0 },
                },
                Fault {
                    at: SimTime::from_secs_f64(1.0),
                    kind: FaultKind::TransformAbort { worker: 1 },
                },
            ],
        };
        assert!(unsorted.validate(2, 8).is_err());
    }

    #[test]
    fn fingerprints_distinguish_plans() {
        let mut a = Vec::new();
        FaultPlan::storm(1, 60.0, 1, 8, 6.0).fingerprint_into(&mut a);
        let mut b = Vec::new();
        FaultPlan::storm(2, 60.0, 1, 8, 6.0).fingerprint_into(&mut b);
        assert_ne!(a, b);
        let mut e = Vec::new();
        FaultPlan::empty().fingerprint_into(&mut e);
        assert_eq!(e, 0u64.to_le_bytes().to_vec());
    }

    #[test]
    fn retry_policy_defaults_are_inert() {
        let p = RetryPolicy::unlimited();
        assert!(!p.bounded());
        assert!(!p.exhausted(1_000_000));
        let now = SimTime::from_secs_f64(3.0);
        assert_eq!(p.next_retry(now, 5), now);
    }

    #[test]
    fn retry_policy_backoff_doubles_and_caps() {
        let p = RetryPolicy { max_attempts: 4, backoff_base_s: 0.2 };
        assert!(p.bounded());
        assert!(!p.exhausted(3));
        assert!(p.exhausted(4));
        let now = SimTime::ZERO;
        let d1 = p.next_retry(now, 1).since(now).as_secs_f64();
        let d2 = p.next_retry(now, 2).since(now).as_secs_f64();
        let d3 = p.next_retry(now, 3).since(now).as_secs_f64();
        assert!((d1 - 0.2).abs() < 1e-9);
        assert!((d2 - 0.4).abs() < 1e-9);
        assert!((d3 - 0.8).abs() < 1e-9);
        // Exponent cap: huge attempt counts stay finite.
        let far = p.next_retry(now, 64).since(now).as_secs_f64();
        assert!((far - 0.2 * 1024.0).abs() < 1e-6);
    }
}
