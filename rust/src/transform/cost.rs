//! Transformation cost model: the scheduler's view of "what would this
//! transformation cost?", and per-step overhead for the executor.

use crate::config::{GpuSpec, ModelConfig};
use crate::kvcache::{run_kv_migration, KvMigrationSpec, KvMigrationStrategy};
use crate::sim::clock::SimDuration;
use crate::weights::{run_weight_migration, WeightMigrationSpec, WeightStrategy};

/// Which transformation machinery an instance uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mechanism {
    /// Full Gyges: header-centric KV + padded weights + overlap.
    Gyges,
    /// Gyges without overlapping (ablation).
    GygesNoOverlap,
    /// Basic migrate+trim KV and partial-swap weights.
    Basic,
    /// Seesaw-style re-shard through CPU shared memory.
    Seesaw,
}

impl Mechanism {
    /// Stable identifier used by snapshots and reports.
    pub fn name(&self) -> &'static str {
        match self {
            Mechanism::Gyges => "gyges",
            Mechanism::GygesNoOverlap => "gyges-",
            Mechanism::Basic => "basic",
            Mechanism::Seesaw => "seesaw",
        }
    }

    pub fn by_name(s: &str) -> Option<Mechanism> {
        match s {
            "gyges" => Some(Mechanism::Gyges),
            "gyges-" => Some(Mechanism::GygesNoOverlap),
            "basic" => Some(Mechanism::Basic),
            "seesaw" => Some(Mechanism::Seesaw),
            _ => None,
        }
    }
}

/// Effective bandwidth factor of Seesaw's CPU-shared-memory path relative
/// to raw PCIe: serialization through host buffers, pageable copies and
/// re-partitioning on the CPU (fits the paper's "up to 41×" §6.2.3).
const SEESAW_SHM_EFFICIENCY: f64 = 0.12;

/// Cost estimate for transforming one instance `from_tp → to_tp`.
#[derive(Clone, Copy, Debug)]
pub struct TransformCost {
    /// Total wall time until the transformation completes.
    pub total: SimDuration,
    /// Extra serving-visible time (spread across steps by staggering).
    pub visible: SimDuration,
    /// Peak extra device memory per worker.
    pub peak_extra_bytes: u64,
    /// Whether serving pauses entirely during the transformation.
    pub blocking: bool,
}

/// Estimate the cost of a full-model transformation.
pub fn estimate(
    model: &ModelConfig,
    gpu: &GpuSpec,
    from_tp: u64,
    to_tp: u64,
    kv_util: f64,
    mech: Mechanism,
) -> TransformCost {
    let layers = model.num_layers;
    let mut kv_spec = KvMigrationSpec::paper_default(model.clone());
    kv_spec.gpu = gpu.clone();
    kv_spec.workers = from_tp.max(to_tp) as u32;
    kv_spec.target_tp = from_tp.max(to_tp);
    kv_spec.kv_util = kv_util;
    let w_spec = WeightMigrationSpec { model: model.clone(), gpu: gpu.clone(), from_tp, to_tp };

    match mech {
        Mechanism::Gyges | Mechanism::GygesNoOverlap | Mechanism::Basic => {
            let (kv_s, w_s) = match mech {
                Mechanism::Gyges => (KvMigrationStrategy::Gyges, WeightStrategy::Gyges),
                Mechanism::GygesNoOverlap => {
                    (KvMigrationStrategy::GygesNoOverlap, WeightStrategy::GygesNoOverlap)
                }
                _ => (KvMigrationStrategy::Basic, WeightStrategy::PartialSwap),
            };
            let kv = run_kv_migration(&kv_spec, kv_s);
            let w = run_weight_migration(&w_spec, w_s);
            TransformCost {
                total: kv.total_wall(layers) + w.total_wall(layers),
                visible: kv.total_visible(layers) + w.total_visible(layers),
                peak_extra_bytes: kv.per_layer_peak_bytes + w.peak_extra_bytes,
                blocking: false,
            }
        }
        Mechanism::Seesaw => {
            // Re-shard via CPU shared memory: weights + KV make a full
            // round trip over PCIe (device→host, re-partition, host→device)
            // and serving blocks meanwhile (§3.3: up to 41× time cost).
            let kv_bytes = (kv_spec.worker_kv_bytes() as f64 * kv_util) as u64;
            let w_bytes = model.total_weight_bytes() / from_tp.max(1);
            let shm = crate::sim::link::Link {
                alpha_us: 50.0,
                bw: gpu.pcie_bw * SEESAW_SHM_EFFICIENCY,
            };
            let t = shm.transfer_time(2 * (kv_bytes + w_bytes));
            TransformCost { total: t, visible: t, peak_extra_bytes: 0, blocking: true }
        }
    }
}

/// Per-serving-step overhead when the transformation staggers
/// `layers_per_step` layers per step (§6.2.3 / Figure 11 x-axis).
pub fn per_step_overhead(
    model: &ModelConfig,
    gpu: &GpuSpec,
    kv_util: f64,
    mech: Mechanism,
    layers_per_step: u64,
) -> SimDuration {
    let c = estimate(model, gpu, 1, 4, kv_util, mech);
    let steps = model.num_layers.div_ceil(layers_per_step.max(1));
    SimDuration(c.visible.0 / steps.max(1))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setting() -> (ModelConfig, GpuSpec) {
        (ModelConfig::qwen2_5_32b(), GpuSpec::h20())
    }

    #[test]
    fn gyges_beats_basic_beats_seesaw() {
        let (m, g) = setting();
        let gy = estimate(&m, &g, 1, 4, 0.9, Mechanism::Gyges);
        let basic = estimate(&m, &g, 1, 4, 0.9, Mechanism::Basic);
        let seesaw = estimate(&m, &g, 1, 4, 0.9, Mechanism::Seesaw);
        assert!(gy.visible < basic.visible);
        assert!(basic.visible < seesaw.visible);
        assert!(seesaw.blocking && !gy.blocking);
    }

    #[test]
    fn seesaw_factor_vs_gyges_large() {
        // §6.2.3: Seesaw costs ~41× more (visible cost, all layers).
        let (m, g) = setting();
        let gy = estimate(&m, &g, 1, 4, 0.9, Mechanism::Gyges);
        let seesaw = estimate(&m, &g, 1, 4, 0.9, Mechanism::Seesaw);
        let factor = seesaw.visible.as_secs_f64() / gy.visible.as_secs_f64();
        assert!((10.0..2000.0).contains(&factor), "factor {factor}");
    }

    #[test]
    fn overlap_ablation_direction() {
        let (m, g) = setting();
        let with = estimate(&m, &g, 1, 4, 0.9, Mechanism::Gyges);
        let without = estimate(&m, &g, 1, 4, 0.9, Mechanism::GygesNoOverlap);
        assert!(with.visible < without.visible);
        assert!(!with.blocking);
    }

    #[test]
    fn per_step_overhead_decreases_with_stagger() {
        let (m, g) = setting();
        let one = per_step_overhead(&m, &g, 0.9, Mechanism::Gyges, 1);
        let all = per_step_overhead(&m, &g, 0.9, Mechanism::Gyges, m.num_layers);
        assert!(one < all, "staggering lowers per-step cost: {one} vs {all}");
    }

    #[test]
    fn scale_down_estimate_works() {
        let (m, g) = setting();
        let c = estimate(&m, &g, 4, 1, 0.3, Mechanism::Gyges);
        assert!(c.total.0 > 0);
    }

    #[test]
    fn gyges_visible_total_is_subsecond() {
        // Premise of Figure 11's <1% overhead at production step times.
        let (m, g) = setting();
        let gy = estimate(&m, &g, 1, 4, 0.9, Mechanism::Gyges);
        assert!(gy.visible.as_secs_f64() < 0.2, "visible {}", gy.visible);
        assert!(gy.total.as_secs_f64() < 2.5, "wall {}", gy.total);
    }
}
