//! Hybrid parallelism transformation (paper §4.3): planning (MLP-first,
//! layer-staggered, reversed), cost estimation for the scheduler, and the
//! step-driven executor behind Figure 11.

pub mod cost;
pub mod executor;
pub mod plan;

pub use cost::{estimate, per_step_overhead, Mechanism, TransformCost};
pub use executor::{fig11_sweep, StepOverheadRow, TransformExec};
pub use plan::{Direction, OpKind, TransformOp, TransformPlan};
