//! Hybrid parallelism transformation planning (§4.3).
//!
//! A transformation is executed module-by-module across layers:
//! * **MLP-first** (scale-up): each layer's MLP weights transform before
//!   its KV cache, releasing memory as early as possible (Figure 8 ①→②).
//! * **Layer-staggered** (scale-down): MLP re-expansions are spread across
//!   inference steps to avoid allocation spikes.
//! * **Reversed traversal**: layers transform from last to first, so
//!   in-flight requests keep the old parallelism until they cross the
//!   transformation boundary and switch exactly once.

use crate::config::ModelConfig;

/// Direction of a transformation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    ScaleUp,
    ScaleDown,
}

/// One unit of transformation work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpKind {
    /// Transform one layer's MLP weights.
    MlpWeights,
    /// Transform one layer's KV cache.
    KvCache,
}

/// One step of the plan: which layer, which module.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransformOp {
    pub layer: u64,
    pub kind: OpKind,
}

/// A complete ordered transformation plan.
#[derive(Clone, Debug)]
pub struct TransformPlan {
    pub direction: Direction,
    pub from_tp: u64,
    pub to_tp: u64,
    pub num_layers: u64,
    /// Ordered ops; executed `ops_per_step` per inference step.
    pub ops: Vec<TransformOp>,
    /// Stagger width: how many layer-ops run per serving step.
    pub ops_per_step: usize,
}

impl TransformPlan {
    /// Build the §4.3 plan for `model` transforming `from_tp → to_tp`,
    /// staggering `layers_per_step` layers per inference step.
    pub fn build(
        model: &ModelConfig,
        from_tp: u64,
        to_tp: u64,
        layers_per_step: usize,
    ) -> TransformPlan {
        assert_ne!(from_tp, to_tp);
        let direction = if to_tp > from_tp { Direction::ScaleUp } else { Direction::ScaleDown };
        let n = model.num_layers;
        let mut ops = Vec::with_capacity(2 * n as usize);
        // Reversed traversal: last layer first.
        for layer in (0..n).rev() {
            match direction {
                Direction::ScaleUp => {
                    // MLP-first: release weight memory before KV needs it.
                    ops.push(TransformOp { layer, kind: OpKind::MlpWeights });
                    ops.push(TransformOp { layer, kind: OpKind::KvCache });
                }
                Direction::ScaleDown => {
                    // KV shrinks first to make room for re-expanded MLP.
                    ops.push(TransformOp { layer, kind: OpKind::KvCache });
                    ops.push(TransformOp { layer, kind: OpKind::MlpWeights });
                }
            }
        }
        TransformPlan {
            direction,
            from_tp,
            to_tp,
            num_layers: n,
            ops,
            ops_per_step: layers_per_step.max(1) * 2,
        }
    }

    /// Number of serving steps the staggered plan spans.
    pub fn num_steps(&self) -> usize {
        self.ops.len().div_ceil(self.ops_per_step)
    }

    /// Ops executed during serving step `step` (0-based).
    pub fn ops_for_step(&self, step: usize) -> &[TransformOp] {
        let lo = step * self.ops_per_step;
        if lo >= self.ops.len() {
            return &[];
        }
        let hi = (lo + self.ops_per_step).min(self.ops.len());
        &self.ops[lo..hi]
    }

    /// The layer index below which (exclusive) layers still run the OLD
    /// parallelism after `step` steps — the transformation boundary a
    /// request crosses at most once (reversed traversal guarantee).
    pub fn boundary_after_step(&self, step: usize) -> u64 {
        let done_ops = ((step + 1) * self.ops_per_step).min(self.ops.len());
        let layers_done = (done_ops / 2) as u64;
        self.num_layers - layers_done
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ModelConfig {
        ModelConfig::qwen2_5_32b()
    }

    #[test]
    fn scale_up_is_mlp_first_reversed() {
        let p = TransformPlan::build(&model(), 1, 4, 1);
        assert_eq!(p.direction, Direction::ScaleUp);
        // first op: LAST layer's MLP
        assert_eq!(p.ops[0], TransformOp { layer: 63, kind: OpKind::MlpWeights });
        assert_eq!(p.ops[1], TransformOp { layer: 63, kind: OpKind::KvCache });
        assert_eq!(p.ops[2].layer, 62);
        assert_eq!(p.ops.len() as u64, 2 * model().num_layers);
    }

    #[test]
    fn scale_down_is_kv_first() {
        let p = TransformPlan::build(&model(), 4, 1, 2);
        assert_eq!(p.direction, Direction::ScaleDown);
        assert_eq!(p.ops[0].kind, OpKind::KvCache);
        assert_eq!(p.ops[1].kind, OpKind::MlpWeights);
    }

    #[test]
    fn stagger_partitions_all_ops() {
        let p = TransformPlan::build(&model(), 1, 4, 3);
        let mut seen = 0;
        for s in 0..p.num_steps() {
            seen += p.ops_for_step(s).len();
        }
        assert_eq!(seen, p.ops.len());
        assert!(p.ops_for_step(p.num_steps()).is_empty());
    }

    #[test]
    fn each_layer_transformed_exactly_once_per_module() {
        let p = TransformPlan::build(&model(), 1, 4, 4);
        let mut mlp = vec![0u32; model().num_layers as usize];
        let mut kv = vec![0u32; model().num_layers as usize];
        for op in &p.ops {
            match op.kind {
                OpKind::MlpWeights => mlp[op.layer as usize] += 1,
                OpKind::KvCache => kv[op.layer as usize] += 1,
            }
        }
        assert!(mlp.iter().all(|&c| c == 1));
        assert!(kv.iter().all(|&c| c == 1));
    }

    #[test]
    fn boundary_monotonically_descends() {
        let p = TransformPlan::build(&model(), 1, 4, 2);
        let mut prev = model().num_layers;
        for s in 0..p.num_steps() {
            let b = p.boundary_after_step(s);
            assert!(b <= prev, "boundary must not ascend");
            prev = b;
        }
        assert_eq!(prev, 0, "all layers transformed at the end");
    }

    #[test]
    fn single_step_transformation() {
        let m = model();
        let p = TransformPlan::build(&m, 1, 4, m.num_layers as usize);
        assert_eq!(p.num_steps(), 1);
    }
}
