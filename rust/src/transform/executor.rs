//! Transformation executor: drives a [`TransformPlan`] against serving
//! steps, producing the per-step overhead series of Figure 11.

use super::cost::{estimate, Mechanism};
use super::plan::TransformPlan;
use crate::config::{GpuSpec, ModelConfig};
use crate::sim::clock::SimDuration;
use crate::sim::EngineModel;

/// Progress of an in-flight transformation on an instance.
#[derive(Clone, Debug)]
pub struct TransformExec {
    pub plan: TransformPlan,
    pub mech: Mechanism,
    /// Per-op visible overhead (derived once from the cost model).
    per_op_visible: SimDuration,
    pub step: usize,
}

impl TransformExec {
    pub fn new(
        model: &ModelConfig,
        gpu: &GpuSpec,
        plan: TransformPlan,
        kv_util: f64,
        mech: Mechanism,
    ) -> TransformExec {
        let cost = estimate(model, gpu, plan.from_tp, plan.to_tp, kv_util, mech);
        let per_op_visible = SimDuration(cost.visible.0 / plan.ops.len().max(1) as u64);
        TransformExec { plan, mech, per_op_visible, step: 0 }
    }

    /// Rebuild a mid-flight executor from snapshot parts. The derived
    /// `per_op_visible` is restored verbatim (it folded the KV
    /// utilization at transform-start time, which no longer exists),
    /// so resumed steps charge exactly the overhead the original would
    /// have.
    pub fn from_parts(
        plan: TransformPlan,
        mech: Mechanism,
        per_op_visible: SimDuration,
        step: usize,
    ) -> TransformExec {
        TransformExec { plan, mech, per_op_visible, step }
    }

    /// The derived per-op visible overhead (snapshot support).
    pub fn per_op_visible(&self) -> SimDuration {
        self.per_op_visible
    }

    /// Advance one serving step; returns the extra visible time this step
    /// absorbs. `None` when the transformation already finished.
    pub fn advance(&mut self) -> Option<SimDuration> {
        let ops = self.plan.ops_for_step(self.step);
        if ops.is_empty() {
            return None;
        }
        let extra = SimDuration(self.per_op_visible.0 * ops.len() as u64);
        self.step += 1;
        Some(extra)
    }

    pub fn done(&self) -> bool {
        self.step >= self.plan.num_steps()
    }

    /// Fraction of layers already transformed.
    pub fn progress(&self) -> f64 {
        (self.step as f64 / self.plan.num_steps() as f64).min(1.0)
    }
}

/// One row of the Figure-11 sweep: step time with `layers_per_step` layers
/// transformed in a single inference step, per mechanism.
#[derive(Clone, Debug)]
pub struct StepOverheadRow {
    pub layers_per_step: u64,
    pub raw_step: SimDuration,
    pub seesaw: SimDuration,
    pub basic: SimDuration,
    pub gyges_no_overlap: SimDuration,
    pub gyges: SimDuration,
}

/// Produce the Figure-11 series: inference step time as the number of
/// layers transformed per step grows from 1 to all layers.
pub fn fig11_sweep(model: &ModelConfig, gpu: &GpuSpec, points: usize) -> Vec<StepOverheadRow> {
    let engine = EngineModel::new(model.clone(), gpu.clone());
    // Raw decode step of a production-loaded TP1 instance (saturated
    // continuous batch — the operating point of §6.2.3).
    let raw = engine.decode_step(1, 32, 4000);
    let max_layers = model.num_layers;
    let mut rows = Vec::new();
    let steps: Vec<u64> = sweep_points(max_layers, points);
    for layers in steps {
        let per = |mech: Mechanism| -> SimDuration {
            let c = estimate(model, gpu, 1, 4, 0.9, mech);
            if c.blocking {
                // Blocking mechanisms stall the step for the whole
                // transformation slice regardless of staggering.
                let slices = max_layers.div_ceil(layers);
                raw + SimDuration(c.total.0 / slices)
            } else {
                let slices = max_layers.div_ceil(layers);
                raw + SimDuration(c.visible.0 / slices)
            }
        };
        rows.push(StepOverheadRow {
            layers_per_step: layers,
            raw_step: raw,
            seesaw: per(Mechanism::Seesaw),
            basic: per(Mechanism::Basic),
            gyges_no_overlap: per(Mechanism::GygesNoOverlap),
            gyges: per(Mechanism::Gyges),
        });
    }
    rows
}

fn sweep_points(max: u64, points: usize) -> Vec<u64> {
    let mut v: Vec<u64> = Vec::new();
    let points = points.max(2);
    for i in 0..points {
        let x = 1.0 + (max as f64 - 1.0) * i as f64 / (points - 1) as f64;
        let x = x.round() as u64;
        if v.last() != Some(&x) {
            v.push(x);
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transform::plan::TransformPlan;

    fn setting() -> (ModelConfig, GpuSpec) {
        (ModelConfig::qwen2_5_32b(), GpuSpec::h20())
    }

    #[test]
    fn executor_runs_to_completion() {
        let (m, g) = setting();
        let plan = TransformPlan::build(&m, 1, 4, 2);
        let mut exec = TransformExec::new(&m, &g, plan, 0.9, Mechanism::Gyges);
        let mut steps = 0;
        let mut total = SimDuration::ZERO;
        while let Some(extra) = exec.advance() {
            total += extra;
            steps += 1;
            assert!(steps < 10_000, "runaway");
        }
        assert!(exec.done());
        assert_eq!(steps, exec.plan.num_steps());
        assert!(total.0 > 0);
    }

    #[test]
    fn fig11_gyges_overhead_near_one_percent() {
        // §6.2.3: Gyges consistently keeps overhead < 1% at fine stagger
        // (we accept up to 2% — see EXPERIMENTS.md).
        let (m, g) = setting();
        let rows = fig11_sweep(&m, &g, 5);
        let first = &rows[0]; // 1 layer per step
        let overhead =
            first.gyges.as_secs_f64() / first.raw_step.as_secs_f64() - 1.0;
        assert!(overhead < 0.02, "overhead {overhead}");
    }

    #[test]
    fn fig11_ordering_holds_everywhere() {
        let (m, g) = setting();
        for row in fig11_sweep(&m, &g, 6) {
            assert!(row.gyges <= row.gyges_no_overlap);
            assert!(row.gyges_no_overlap <= row.basic);
            assert!(row.basic <= row.seesaw, "layers={}", row.layers_per_step);
            assert!(row.raw_step <= row.gyges);
        }
    }

    #[test]
    fn fig11_seesaw_reduction_matches_paper_scale() {
        // §6.2.3: transforming all layers in one step, Gyges cuts the
        // extra cost by ~97.2% vs Seesaw.
        let (m, g) = setting();
        let rows = fig11_sweep(&m, &g, 6);
        let last = rows.last().unwrap();
        let gy_extra = last.gyges.as_secs_f64() - last.raw_step.as_secs_f64();
        let ss_extra = last.seesaw.as_secs_f64() - last.raw_step.as_secs_f64();
        let cut = 1.0 - gy_extra / ss_extra;
        assert!(cut > 0.90, "cut {cut}");
    }

    #[test]
    fn sweep_points_cover_range() {
        let pts = sweep_points(64, 6);
        assert_eq!(*pts.first().unwrap(), 1);
        assert_eq!(*pts.last().unwrap(), 64);
    }
}
