//! Collective-communication timing: all-to-all with SM contention and
//! independent-stream overlap.
//!
//! §4.1 "Overlapping": all-to-all needs GPU SMs; Gyges launches it on an
//! independent stream that runs when free SMs are available. We model the
//! effective bandwidth of an all-to-all as a saturating function of the
//! SM count assigned to the copy kernels, calibrated against the paper's
//! two anchors (Qwen2.5-32B full-KV move: 522 ms @ 78 SMs, 2240 ms @ 1 SM).

use super::clock::SimDuration;
use super::link::Link;
use crate::config::calib::transform as calib;

/// SM-dependent efficiency: eff(sm) = sm / (sm + K). K is fit so that
/// eff(78)/eff(1) equals the paper's 2240/522 ≈ 4.29× ratio.
pub const SM_HALF_SATURATION: f64 = 3.48;

/// All-to-all effective *aggregate* bandwidth calibration. The paper's
/// 522 ms for moving ~52 GB implies an aggregate effective bandwidth far
/// below raw NVLink (the move also rewrites pages on-device); we fold that
/// into a single efficiency factor fit below in `calibrate_a2a_eff`.
#[derive(Clone, Copy, Debug)]
pub struct CommModel {
    /// Per-direction NVLink bandwidth (bytes/s) of the underlying link.
    pub link: Link,
    /// Fraction of raw link bandwidth an all-to-all achieves at full SMs
    /// (captures protocol + on-device rewrite overhead).
    pub a2a_efficiency: f64,
    /// Total SMs on the device.
    pub sm_total: u32,
}

impl CommModel {
    /// Build from a GPU spec with the paper-calibrated efficiency.
    pub fn for_gpu(gpu: &crate::config::GpuSpec) -> CommModel {
        CommModel {
            link: Link::nvlink(gpu.nvlink_bw),
            a2a_efficiency: calibrate_a2a_eff(gpu),
            sm_total: gpu.sm_count,
        }
    }

    fn sm_eff(&self, sms: u32) -> f64 {
        let s = sms.max(1) as f64;
        let full = self.sm_total as f64;
        (s / (s + SM_HALF_SATURATION)) / (full / (full + SM_HALF_SATURATION))
    }

    /// Time for an all-to-all where each of `workers` ranks sends a total
    /// of `bytes_per_worker` (split among the other ranks), using `sms`
    /// SMs per rank for the copy kernels.
    pub fn all_to_all(&self, workers: u32, bytes_per_worker: u64, sms: u32) -> SimDuration {
        if workers <= 1 || bytes_per_worker == 0 {
            return SimDuration::ZERO;
        }
        // Per-rank effective bandwidth; ranks proceed in parallel so the
        // wall time is one rank's send time plus a small per-peer latency.
        let bw = self.link.bw * self.a2a_efficiency * self.sm_eff(sms);
        let peers = (workers - 1) as f64;
        SimDuration::from_micros_f64(
            self.link.alpha_us * peers + bytes_per_worker as f64 / bw * 1e6,
        )
    }

    /// Time for a phased all-to-all in `stages` stages moving the same
    /// total volume; each stage pays the latency term once per peer but
    /// pipelines metadata exchange inside the stage (§4.1.2 "phased KV
    /// cache migration" — time is ~unchanged, peak memory shrinks).
    pub fn all_to_all_phased(
        &self,
        workers: u32,
        bytes_per_worker: u64,
        sms: u32,
        stages: u32,
    ) -> SimDuration {
        if workers <= 1 || bytes_per_worker == 0 {
            return SimDuration::ZERO;
        }
        let stages = stages.max(1);
        let per_stage = self.all_to_all(workers, bytes_per_worker / stages as u64, sms);
        // metadata exchange per stage: one small message per peer
        let meta = SimDuration::from_micros_f64(self.link.alpha_us * (workers - 1) as f64);
        let mut total = SimDuration::ZERO;
        for _ in 0..stages {
            total += per_stage + meta;
        }
        total
    }

    /// Per-layer tensor-parallel all-reduce time for `bytes` of
    /// activations across `tp` workers (ring: 2(tp−1)/tp volume factor).
    pub fn allreduce(&self, tp: u32, bytes: u64) -> SimDuration {
        if tp <= 1 || bytes == 0 {
            return SimDuration::ZERO;
        }
        let factor = 2.0 * (tp as f64 - 1.0) / tp as f64;
        // All-reduce uses NCCL's tuned kernels: near-raw link efficiency.
        let bw = self.link.bw * 0.8;
        SimDuration::from_micros_f64(
            2.0 * self.link.alpha_us + bytes as f64 * factor / bw * 1e6,
        )
    }
}

/// Fit the all-to-all efficiency so that moving the paper's Qwen2.5-32B
/// 90%-utilization KV working set (4×TP1→TP4) takes 522 ms at 78 SMs.
pub fn calibrate_a2a_eff(gpu: &crate::config::GpuSpec) -> f64 {
    // Paper setting: Qwen2.5-32B on H20. Each TP1 worker's KV capacity is
    // HBM − weights − activations; at 90% utilization each worker sends
    // 3/4 of its KV (keeps its own head shard).
    let model = crate::config::ModelConfig::qwen2_5_32b();
    let h20 = crate::config::GpuSpec::h20();
    let kv_cap = h20.hbm_bytes as f64
        - model.total_weight_bytes() as f64
        - crate::config::calib::memory::ACTIVATION_BYTES as f64;
    let bytes_sent_per_worker = kv_cap * 0.9 * 0.75;
    let target_s = calib::KV_MOVE_MS_78SM / 1e3;
    // bytes / (link_bw * eff) = target  (latency term negligible at GBs)
    let eff_h20 = bytes_sent_per_worker / (h20.nvlink_bw * target_s);
    // Assume the protocol efficiency is a property of the software stack,
    // identical across GPU types.
    let _ = gpu;
    eff_h20.clamp(0.01, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GpuSpec;

    fn h20_model() -> CommModel {
        CommModel::for_gpu(&GpuSpec::h20())
    }

    #[test]
    fn anchor_522ms_at_78_sms() {
        let m = h20_model();
        let model = crate::config::ModelConfig::qwen2_5_32b();
        let h20 = GpuSpec::h20();
        let kv_cap = h20.hbm_bytes as f64
            - model.total_weight_bytes() as f64
            - crate::config::calib::memory::ACTIVATION_BYTES as f64;
        let sent = (kv_cap * 0.9 * 0.75) as u64;
        let t = m.all_to_all(4, sent, 78);
        let ms = t.as_millis_f64();
        assert!((ms - 522.0).abs() / 522.0 < 0.05, "got {ms} ms");
    }

    #[test]
    fn anchor_ratio_1sm_vs_78sm() {
        let m = h20_model();
        let sent = 10_000_000_000u64;
        let fast = m.all_to_all(4, sent, 78).as_secs_f64();
        let slow = m.all_to_all(4, sent, 1).as_secs_f64();
        let ratio = slow / fast;
        let paper = calib::KV_MOVE_MS_1SM / calib::KV_MOVE_MS_78SM;
        assert!((ratio - paper).abs() / paper < 0.08, "ratio {ratio} vs paper {paper}");
    }

    #[test]
    fn phased_time_close_to_single_shot() {
        let m = h20_model();
        let sent = 10_000_000_000u64;
        let one = m.all_to_all(4, sent, 78).as_secs_f64();
        let phased = m.all_to_all_phased(4, sent, 78, 8).as_secs_f64();
        assert!(phased >= one);
        assert!(phased / one < 1.15, "phased {phased} vs {one}");
    }

    #[test]
    fn allreduce_scales_with_tp() {
        let m = h20_model();
        let t1 = m.allreduce(1, 1_000_000);
        let t2 = m.allreduce(2, 1_000_000);
        let t4 = m.allreduce(4, 1_000_000);
        assert_eq!(t1, SimDuration::ZERO);
        assert!(t4 > t2);
    }

    #[test]
    fn zero_and_single_worker_are_free() {
        let m = h20_model();
        assert_eq!(m.all_to_all(1, 1 << 30, 78), SimDuration::ZERO);
        assert_eq!(m.all_to_all(4, 0, 78), SimDuration::ZERO);
    }
}
