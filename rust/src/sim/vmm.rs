//! CUDA virtual-memory-management model: 2 MiB-granularity pages with
//! driver-call latencies (cuMemCreate/Map/Unmap/SetAccess).
//!
//! The paper's Challenge-1 hinges on this layer: weights and KV cache are
//! carved out of page-granular physical allocations, and the key property
//! exploited by overlapping (§4.1/§4.2) is that driver calls run on the
//! CPU, *in parallel with GPU kernels* — unlike copies/all-to-alls which
//! need SMs.

use super::clock::SimDuration;
use crate::util::bytes::VMM_PAGE;
use std::collections::BTreeSet;

/// Latency model for the driver calls (measured-order-of-magnitude
/// constants; only ratios between strategies matter).
#[derive(Clone, Debug)]
pub struct VmmCosts {
    /// Fixed per-call overhead.
    pub call_us: f64,
    /// Additional cost per page touched by a map/unmap/set-access.
    pub per_page_us: f64,
}

impl Default for VmmCosts {
    fn default() -> Self {
        // cuMemMap and friends are tens-of-µs calls; batching pages into a
        // single call amortizes the fixed part.
        VmmCosts { call_us: 25.0, per_page_us: 1.5 }
    }
}

impl VmmCosts {
    /// Time to (un)map `pages` pages in one batched driver call.
    pub fn op_time(&self, pages: u64) -> SimDuration {
        SimDuration::from_micros_f64(self.call_us + self.per_page_us * pages as f64)
    }

    /// Time for `calls` separate driver calls of `pages_each` pages.
    pub fn op_time_calls(&self, calls: u64, pages_each: u64) -> SimDuration {
        SimDuration::from_micros_f64(
            (self.call_us + self.per_page_us * pages_each as f64) * calls as f64,
        )
    }
}

/// Error type for the page pool.
#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum VmmError {
    #[error("out of device pages: requested {requested}, free {free}")]
    OutOfPages { requested: u64, free: u64 },
    #[error("page {0} is not allocated")]
    NotAllocated(u64),
    #[error("double free of page {0}")]
    DoubleFree(u64),
}

/// Physical page pool of one GPU: tracks which 2 MiB pages are committed.
///
/// Page ids are dense indices into the device's physical space; the pool
/// also records the high-water mark so benches can report peak usage.
#[derive(Clone, Debug)]
pub struct PagePool {
    total_pages: u64,
    free: BTreeSet<u64>,
    allocated: BTreeSet<u64>,
    peak_allocated: u64,
}

impl PagePool {
    /// A pool over `capacity_bytes` of device memory.
    pub fn new(capacity_bytes: u64) -> PagePool {
        let total_pages = capacity_bytes / VMM_PAGE;
        PagePool {
            total_pages,
            free: (0..total_pages).collect(),
            allocated: BTreeSet::new(),
            peak_allocated: 0,
        }
    }

    pub fn total_pages(&self) -> u64 {
        self.total_pages
    }

    pub fn free_pages(&self) -> u64 {
        self.free.len() as u64
    }

    pub fn allocated_pages(&self) -> u64 {
        self.allocated.len() as u64
    }

    pub fn allocated_bytes(&self) -> u64 {
        self.allocated_pages() * VMM_PAGE
    }

    /// Highest simultaneous allocation seen (pages).
    pub fn peak_allocated_pages(&self) -> u64 {
        self.peak_allocated
    }

    /// Reset the peak tracker to the current level (bench helper).
    pub fn reset_peak(&mut self) {
        self.peak_allocated = self.allocated.len() as u64;
    }

    /// Allocate `n` pages; returns their ids (ascending).
    pub fn alloc(&mut self, n: u64) -> Result<Vec<u64>, VmmError> {
        if (self.free.len() as u64) < n {
            return Err(VmmError::OutOfPages { requested: n, free: self.free.len() as u64 });
        }
        let ids: Vec<u64> = self.free.iter().take(n as usize).copied().collect();
        for id in &ids {
            self.free.remove(id);
            self.allocated.insert(*id);
        }
        self.peak_allocated = self.peak_allocated.max(self.allocated.len() as u64);
        Ok(ids)
    }

    /// Free previously allocated pages.
    pub fn release(&mut self, ids: &[u64]) -> Result<(), VmmError> {
        for &id in ids {
            if !self.allocated.remove(&id) {
                return if self.free.contains(&id) {
                    Err(VmmError::DoubleFree(id))
                } else {
                    Err(VmmError::NotAllocated(id))
                };
            }
            self.free.insert(id);
        }
        Ok(())
    }

    /// Allocate enough pages to hold `bytes`.
    pub fn alloc_bytes(&mut self, bytes: u64) -> Result<Vec<u64>, VmmError> {
        self.alloc(bytes.div_ceil(VMM_PAGE))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::MIB;

    #[test]
    fn alloc_and_release_roundtrip() {
        let mut p = PagePool::new(20 * MIB); // 10 pages
        assert_eq!(p.total_pages(), 10);
        let ids = p.alloc(4).unwrap();
        assert_eq!(ids.len(), 4);
        assert_eq!(p.free_pages(), 6);
        p.release(&ids).unwrap();
        assert_eq!(p.free_pages(), 10);
    }

    #[test]
    fn oom_reported() {
        let mut p = PagePool::new(4 * MIB); // 2 pages
        assert_eq!(
            p.alloc(3),
            Err(VmmError::OutOfPages { requested: 3, free: 2 })
        );
    }

    #[test]
    fn double_free_detected() {
        let mut p = PagePool::new(4 * MIB);
        let ids = p.alloc(1).unwrap();
        p.release(&ids).unwrap();
        assert_eq!(p.release(&ids), Err(VmmError::DoubleFree(ids[0])));
    }

    #[test]
    fn peak_tracking() {
        let mut p = PagePool::new(20 * MIB);
        let a = p.alloc(6).unwrap();
        p.release(&a[..4]).unwrap();
        let _b = p.alloc(1).unwrap();
        assert_eq!(p.peak_allocated_pages(), 6);
        p.reset_peak();
        assert_eq!(p.peak_allocated_pages(), 3);
    }

    #[test]
    fn op_time_scales_with_pages() {
        let c = VmmCosts::default();
        assert!(c.op_time(100) > c.op_time(1));
        // one batched call is cheaper than many small calls
        assert!(c.op_time(64) < c.op_time_calls(64, 1));
    }

    #[test]
    fn alloc_bytes_rounds_up() {
        let mut p = PagePool::new(20 * MIB);
        let ids = p.alloc_bytes(3 * MIB).unwrap(); // 1.5 pages → 2
        assert_eq!(ids.len(), 2);
    }
}
