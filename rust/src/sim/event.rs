//! Discrete-event queue: time-ordered, FIFO-stable for equal timestamps.

use super::clock::SimTime;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<E> {
    time: SimTime,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap behaviour on BinaryHeap (max-heap).
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// A deterministic discrete-event queue.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: SimTime,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0, now: SimTime::ZERO }
    }

    /// Current simulated time (advances on `pop`).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `payload` at absolute time `at`. Scheduling in the past is
    /// clamped to `now` (can occur with zero-duration stages).
    pub fn push(&mut self, at: SimTime, payload: E) {
        let t = if at < self.now { self.now } else { at };
        self.heap.push(Entry { time: t, seq: self.seq, payload });
        self.seq += 1;
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let e = self.heap.pop()?;
        self.now = e.time;
        Some((e.time, e.payload))
    }

    /// Peek at the next event time without advancing.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.time)
    }

    /// Advance the clock to `t` without popping (never moves backwards).
    /// Used when the driver consumes work from a side stream (e.g. a
    /// streamed trace arrival) so that subsequent past-time pushes still
    /// clamp against true simulated time.
    pub fn advance_to(&mut self, t: SimTime) {
        if t > self.now {
            self.now = t;
        }
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Value of the internal sequence counter (snapshot support).
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Every queued entry as `(time, seq, &payload)`, ascending by
    /// `(time, seq)` — exactly the order [`EventQueue::pop`] would
    /// deliver them. Heap iteration order is arbitrary, so this sorts a
    /// copy of the handles; O(n log n), called only when snapshotting.
    pub fn entries(&self) -> Vec<(SimTime, u64, &E)> {
        let mut v: Vec<(SimTime, u64, &E)> =
            self.heap.iter().map(|e| (e.time, e.seq, &e.payload)).collect();
        v.sort_by_key(|&(t, s, _)| (t, s));
        v
    }

    /// Rebuild a queue from snapshot parts. Entries keep their original
    /// sequence numbers, so FIFO tie-breaking — and the interleaving
    /// with post-restore pushes (which continue from `seq`) — is
    /// identical to the never-paused queue.
    pub fn restore(
        now: SimTime,
        seq: u64,
        entries: Vec<(SimTime, u64, E)>,
    ) -> Result<EventQueue<E>, String> {
        let mut heap = BinaryHeap::with_capacity(entries.len());
        for (time, s, payload) in entries {
            if time < now {
                return Err(format!(
                    "event queue restore: entry at {} ns is before the clock ({} ns)",
                    time.0, now.0
                ));
            }
            if s >= seq {
                return Err(format!(
                    "event queue restore: entry seq {s} is not below the counter {seq}"
                ));
            }
            heap.push(Entry { time, seq: s, payload });
        }
        Ok(EventQueue { heap, seq, now })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::clock::SimDuration;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(SimTime(30), "c");
        q.push(SimTime(10), "a");
        q.push(SimTime(20), "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn fifo_for_equal_times() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push(SimTime(5), i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn clock_advances() {
        let mut q = EventQueue::new();
        q.push(SimTime::from_secs_f64(1.0), ());
        q.pop();
        assert!((q.now().as_secs_f64() - 1.0).abs() < 1e-9);
        // past event clamps to now
        q.push(SimTime::ZERO, ());
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, q.now());
        assert!((t.as_secs_f64() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn advance_to_is_monotonic() {
        let mut q = EventQueue::<()>::new();
        q.advance_to(SimTime(50));
        assert_eq!(q.now(), SimTime(50));
        q.advance_to(SimTime(20)); // never backwards
        assert_eq!(q.now(), SimTime(50));
        // past pushes clamp against the advanced clock
        q.push(SimTime(10), ());
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, SimTime(50));
    }

    #[test]
    fn snapshot_restore_preserves_pop_order_and_ties() {
        let mut q = EventQueue::new();
        for i in 0..5 {
            q.push(SimTime(40), i); // equal timestamps: FIFO by seq
        }
        q.push(SimTime(10), 100);
        q.push(SimTime(20), 101);
        q.pop(); // consume the t=10 entry, clock now 10
        let entries: Vec<(SimTime, u64, i32)> =
            q.entries().into_iter().map(|(t, s, &p)| (t, s, p)).collect();
        let mut restored = EventQueue::restore(q.now(), q.seq(), entries).unwrap();
        // Future pushes interleave identically on both queues.
        q.push(SimTime(40), 200);
        restored.push(SimTime(40), 200);
        let drain = |q: &mut EventQueue<i32>| -> Vec<(u64, i32)> {
            std::iter::from_fn(|| q.pop().map(|(t, e)| (t.0, e))).collect()
        };
        assert_eq!(drain(&mut q), drain(&mut restored));
        // A stale entry (before the clock) or seq at/over the counter is
        // refused.
        assert!(EventQueue::restore(SimTime(50), 10, vec![(SimTime(40), 3, ())]).is_err());
        assert!(EventQueue::restore(SimTime(0), 2, vec![(SimTime(40), 2, ())]).is_err());
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::new();
        q.push(SimTime(10), 1);
        let (t, v) = q.pop().unwrap();
        assert_eq!((t.0, v), (10, 1));
        q.push(t + SimDuration(5), 2);
        q.push(t + SimDuration(3), 3);
        assert_eq!(q.pop().unwrap().1, 3);
        assert_eq!(q.pop().unwrap().1, 2);
        assert!(q.is_empty());
    }
}
