//! Discrete-event queue: time-ordered, FIFO-stable for equal timestamps.
//!
//! Two backends implement the exact same `(time, seq)` contract:
//!
//! * **Calendar** (default): a hashed calendar queue / timing wheel — the
//!   classic DES structure (Brown 1988). Entries hash into `2^k` bucket
//!   heaps by "day" (`time >> shift`); push and pop are O(1) amortized
//!   instead of the heap's O(log n), which is what keeps a 10k-instance
//!   fleet's event loop flat as the queue grows (PERF.md).
//! * **Heap**: the original `BinaryHeap` reference implementation, kept
//!   behind `--queue heap` for bisection and as the property-test oracle
//!   (`rust/tests/queue_equivalence.rs` proves identical pop streams).
//!
//! The backend is a process-wide default ([`set_queue_backend`]) chosen
//! by the `--queue` CLI/bench knob. It is deliberately NOT part of
//! `ClusterConfig`, the config fingerprint, or snapshots: both backends
//! pop the identical `(time, seq)` stream, so figure outputs and
//! snapshot bytes are backend-agnostic and a snapshot taken under one
//! backend resumes byte-identically under the other (CI `cmp`s fig12
//! JSONL across backends to enforce this).

use super::clock::SimTime;
use std::cell::Cell;
use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU8, Ordering as AtomicOrdering};

/// Which `EventQueue` implementation backs new queues.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueueBackend {
    /// Hashed calendar queue (O(1) amortized; the default).
    Calendar,
    /// Binary heap (O(log n); reference/bisection backend).
    Heap,
}

impl QueueBackend {
    pub fn name(&self) -> &'static str {
        match self {
            QueueBackend::Calendar => "calendar",
            QueueBackend::Heap => "heap",
        }
    }

    pub fn by_name(s: &str) -> Option<QueueBackend> {
        match s {
            "calendar" => Some(QueueBackend::Calendar),
            "heap" => Some(QueueBackend::Heap),
            _ => None,
        }
    }
}

/// Process-wide default backend (0 = calendar, 1 = heap). Relaxed is
/// enough: the knob is set once at startup before any queue exists, and
/// every load sees a fully-initialized value either way.
static DEFAULT_BACKEND: AtomicU8 = AtomicU8::new(0);

/// Set the process-wide default backend (the `--queue` knob). Affects
/// queues constructed afterwards; existing queues keep their backend.
pub fn set_queue_backend(b: QueueBackend) {
    let v = match b {
        QueueBackend::Calendar => 0,
        QueueBackend::Heap => 1,
    };
    DEFAULT_BACKEND.store(v, AtomicOrdering::Relaxed);
}

/// The current process-wide default backend.
pub fn queue_backend() -> QueueBackend {
    match DEFAULT_BACKEND.load(AtomicOrdering::Relaxed) {
        1 => QueueBackend::Heap,
        _ => QueueBackend::Calendar,
    }
}

struct Entry<E> {
    time: SimTime,
    seq: u64,
    payload: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap behaviour on BinaryHeap (max-heap).
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Smallest bucket count (power of two).
const MIN_BUCKETS: usize = 16;
/// Bucket-width bounds, as log2(nanoseconds): 2^10 ns ≈ 1 µs up to
/// 2^33 ns ≈ 8.6 s. The sim's event gaps (decode steps ~10–100 ms,
/// transforms ~seconds) always land inside this window.
const MIN_SHIFT: u32 = 10;
const MAX_SHIFT: u32 = 33;
/// Initial bucket width: 2^20 ns ≈ 1 ms (typical step granularity).
const INITIAL_SHIFT: u32 = 20;

/// Hashed calendar queue. Entries live in `buckets[day & mask]` where
/// `day = time.0 >> shift`; each bucket is a small min-heap (via the
/// reversed [`Entry`] order), so all entries of one day sit in exactly
/// one bucket and the bucket top is that bucket's `(time, seq)` minimum.
///
/// Finding the global minimum walks days upward from a proven lower
/// bound (`floor_day`): the first bucket whose top belongs to the walked
/// day holds the global minimum, because every entry of an earlier day
/// would sit in an already-walked bucket. A walk that completes one full
/// revolution without a hit (entries sparser than one revolution) falls
/// back to an O(buckets) scan of the bucket tops. Both paths cache the
/// result in `min_hint` so `peek_time` + `pop` share one search.
///
/// Resizes are deterministic and integer-only: bucket count tracks
/// `len.next_power_of_two()` (×2 hysteresis both ways) and the bucket
/// width re-fits to `2 × span/(len-1)` clamped to [2^10, 2^33] ns, so
/// the walk stays O(1) amortized whatever the event density. Drained
/// bucket storage and the resize scratch vector are reused, not
/// reallocated.
struct Calendar<E> {
    buckets: Vec<BinaryHeap<Entry<E>>>,
    /// `buckets.len() - 1`; bucket count is always a power of two.
    mask: u64,
    /// log2 of the bucket width in nanoseconds.
    shift: u32,
    len: usize,
    /// Proven lower bound on `time.0 >> shift` over all queued entries.
    /// `Cell`: the min-search runs under `&self` (peek path) and may
    /// tighten the bound as it proves days empty.
    floor_day: Cell<u64>,
    /// Cached global minimum `(time, seq, bucket)`; cleared on pop and
    /// resize, tightened on insert.
    min_hint: Cell<Option<(SimTime, u64, u32)>>,
    /// Recycled scratch for resizes (entries in flight between layouts).
    spare: Vec<Entry<E>>,
}

impl<E> Calendar<E> {
    fn new() -> Calendar<E> {
        Calendar {
            buckets: (0..MIN_BUCKETS).map(|_| BinaryHeap::new()).collect(),
            mask: (MIN_BUCKETS - 1) as u64,
            shift: INITIAL_SHIFT,
            len: 0,
            floor_day: Cell::new(0),
            min_hint: Cell::new(None),
            spare: Vec::new(),
        }
    }

    #[inline]
    fn day(&self, t: SimTime) -> u64 {
        t.0 >> self.shift
    }

    #[inline]
    fn bucket_of_day(&self, day: u64) -> usize {
        (day & self.mask) as usize
    }

    fn insert(&mut self, e: Entry<E>) {
        let day = self.day(e.time);
        if self.len == 0 {
            self.floor_day.set(day);
        } else if day < self.floor_day.get() {
            self.floor_day.set(day);
        }
        if let Some((ht, hs, _)) = self.min_hint.get() {
            if (e.time, e.seq) < (ht, hs) {
                let b = self.bucket_of_day(day) as u32;
                self.min_hint.set(Some((e.time, e.seq, b)));
            }
        }
        let b = self.bucket_of_day(day);
        self.buckets[b].push(e);
        self.len += 1;
        if self.len > 2 * self.buckets.len() {
            self.resize();
        }
    }

    /// Locate the global `(time, seq)` minimum without removing it.
    fn find_min(&self) -> Option<(SimTime, u64, u32)> {
        if self.len == 0 {
            return None;
        }
        if let Some(h) = self.min_hint.get() {
            return Some(h);
        }
        // Walk days upward from the floor. Each visited day maps to one
        // bucket; a top entry of exactly that day is the global minimum
        // (all earlier days are proven empty, and within a day the
        // bucket heap already orders by (time, seq)). A bucket that is
        // empty — or whose top belongs to a later day — proves the
        // walked day empty, which lets the floor advance. One revolution
        // of consecutive days covers every bucket exactly once (the
        // count is a power of two), so the walk doubles as a full scan
        // of the bucket tops: if no top lands on its walked day, the
        // best top seen IS the global minimum (entries sparser than one
        // revolution), with no second pass.
        let nbuckets = self.buckets.len();
        let mut d = self.floor_day.get();
        let mut best: Option<(SimTime, u64, u32)> = None;
        for _ in 0..nbuckets {
            let b = self.bucket_of_day(d);
            if let Some(top) = self.buckets[b].peek() {
                debug_assert!(self.day(top.time) >= d, "floor_day invariant violated");
                if self.day(top.time) == d {
                    self.floor_day.set(d);
                    let hit = (top.time, top.seq, b as u32);
                    self.min_hint.set(Some(hit));
                    return Some(hit);
                }
                let cand = (top.time, top.seq, b as u32);
                if best.map(|(t, s, _)| (cand.0, cand.1) < (t, s)).unwrap_or(true) {
                    best = Some(cand);
                }
            }
            d += 1;
            self.floor_day.set(d);
        }
        // gyges-lint: allow(D06) find_min is only reached with len > 0, so some bucket is nonempty
        let hit = best.expect("len > 0 but no bucket has entries");
        self.floor_day.set(self.day(hit.0));
        self.min_hint.set(Some(hit));
        Some(hit)
    }

    /// Clock hook from [`EventQueue::advance_to`]: an idle-gap advance
    /// over an EMPTY calendar jumps the walk floor to the advanced day,
    /// so the next repopulation's min-walk skips every day the gap
    /// proved empty instead of revving through them. Only legal when
    /// nothing is queued — already-queued entries may legally precede
    /// the advanced clock (`pop_can_move_clock_backwards_after_advance`)
    /// and bound the floor from below.
    fn advance_to(&self, t: SimTime) {
        if self.len == 0 && self.day(t) > self.floor_day.get() {
            self.floor_day.set(self.day(t));
        }
    }

    fn pop_min(&mut self) -> Option<Entry<E>> {
        let (time, seq, b) = self.find_min()?;
        // gyges-lint: allow(D06) find_min just verified this bucket holds the global minimum
        let e = self.buckets[b as usize].pop().expect("hinted bucket is empty");
        debug_assert!(e.time == time && e.seq == seq, "min hint diverged from bucket top");
        self.len -= 1;
        self.min_hint.set(None);
        // floor_day stays valid: the popped entry was the global minimum,
        // so every remaining entry's day is >= its day >= floor_day.
        if self.buckets.len() > MIN_BUCKETS && self.len * 4 < self.buckets.len() {
            self.resize();
        }
        Some(e)
    }

    /// Re-fit bucket count and width to the current population, reusing
    /// bucket storage and the scratch vector across layouts.
    fn resize(&mut self) {
        let mut spare = std::mem::take(&mut self.spare);
        debug_assert!(spare.is_empty());
        for heap in &mut self.buckets {
            spare.extend(heap.drain());
        }
        debug_assert_eq!(spare.len(), self.len);
        let n = self.len.max(MIN_BUCKETS).next_power_of_two();
        if spare.len() >= 2 {
            let mut tmin = u64::MAX;
            let mut tmax = 0u64;
            for e in &spare {
                tmin = tmin.min(e.time.0);
                tmax = tmax.max(e.time.0);
            }
            let span = tmax - tmin;
            if span > 0 {
                // Bucket width ≈ 2× the mean inter-event gap: dense
                // enough that the min-walk hits within a day or two,
                // sparse enough that one day holds O(1) entries.
                let width = ((span / (spare.len() as u64 - 1)) * 2).max(1);
                self.shift = width.ilog2().clamp(MIN_SHIFT, MAX_SHIFT);
            }
        }
        self.buckets.resize_with(n, BinaryHeap::new);
        self.buckets.truncate(n);
        self.mask = (n - 1) as u64;
        let mut floor = u64::MAX;
        for e in &spare {
            floor = floor.min(self.day(e.time));
        }
        self.floor_day.set(if floor == u64::MAX { 0 } else { floor });
        self.min_hint.set(None);
        for e in spare.drain(..) {
            let b = self.bucket_of_day(self.day(e.time));
            self.buckets[b].push(e);
        }
        self.spare = spare;
    }

    fn iter(&self) -> impl Iterator<Item = &Entry<E>> {
        self.buckets.iter().flat_map(|b| b.iter())
    }
}

enum Backend<E> {
    Heap(BinaryHeap<Entry<E>>),
    Calendar(Calendar<E>),
}

/// A deterministic discrete-event queue.
pub struct EventQueue<E> {
    backend: Backend<E>,
    seq: u64,
    now: SimTime,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// A queue on the process-wide default backend ([`queue_backend`]).
    pub fn new() -> Self {
        Self::with_backend(queue_backend())
    }

    /// A queue on an explicit backend (tests, equivalence harnesses).
    pub fn with_backend(kind: QueueBackend) -> Self {
        let backend = match kind {
            QueueBackend::Heap => Backend::Heap(BinaryHeap::new()),
            QueueBackend::Calendar => Backend::Calendar(Calendar::new()),
        };
        EventQueue { backend, seq: 0, now: SimTime::ZERO }
    }

    /// Which backend this queue runs on.
    pub fn backend(&self) -> QueueBackend {
        match self.backend {
            Backend::Heap(_) => QueueBackend::Heap,
            Backend::Calendar(_) => QueueBackend::Calendar,
        }
    }

    /// Current simulated time (advances on `pop`).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `payload` at absolute time `at`. Scheduling in the past is
    /// clamped to `now` (can occur with zero-duration stages).
    pub fn push(&mut self, at: SimTime, payload: E) {
        let t = if at < self.now { self.now } else { at };
        let e = Entry { time: t, seq: self.seq, payload };
        match &mut self.backend {
            Backend::Heap(h) => h.push(e),
            Backend::Calendar(c) => c.insert(e),
        }
        self.seq += 1;
    }

    /// Pop the earliest event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let e = match &mut self.backend {
            Backend::Heap(h) => h.pop()?,
            Backend::Calendar(c) => c.pop_min()?,
        };
        self.now = e.time;
        Some((e.time, e.payload))
    }

    /// Peek at the next event time without advancing.
    pub fn peek_time(&self) -> Option<SimTime> {
        match &self.backend {
            Backend::Heap(h) => h.peek().map(|e| e.time),
            Backend::Calendar(c) => c.find_min().map(|(t, _, _)| t),
        }
    }

    /// Advance the clock to `t` without popping (never moves backwards).
    /// Used when the driver consumes work from a side stream (e.g. a
    /// streamed trace arrival) so that subsequent past-time pushes still
    /// clamp against true simulated time. On the calendar backend an
    /// empty-queue advance also fast-forwards the min-walk floor, so a
    /// long idle gap is skipped lazily instead of walked day by day.
    pub fn advance_to(&mut self, t: SimTime) {
        if t > self.now {
            self.now = t;
            if let Backend::Calendar(c) = &self.backend {
                c.advance_to(t);
            }
        }
    }

    pub fn len(&self) -> usize {
        match &self.backend {
            Backend::Heap(h) => h.len(),
            Backend::Calendar(c) => c.len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Value of the internal sequence counter (snapshot support).
    pub fn seq(&self) -> u64 {
        self.seq
    }

    /// Every queued entry as `(time, seq, &payload)`, ascending by
    /// `(time, seq)` — exactly the order [`EventQueue::pop`] would
    /// deliver them. Backend iteration order is arbitrary, so this sorts
    /// a copy of the handles; O(n log n), called only when snapshotting.
    /// Both backends produce identical output, which is what keeps
    /// snapshot bytes backend-agnostic.
    pub fn entries(&self) -> Vec<(SimTime, u64, &E)> {
        let mut v: Vec<(SimTime, u64, &E)> = match &self.backend {
            Backend::Heap(h) => h.iter().map(|e| (e.time, e.seq, &e.payload)).collect(),
            Backend::Calendar(c) => c.iter().map(|e| (e.time, e.seq, &e.payload)).collect(),
        };
        v.sort_by_key(|&(t, s, _)| (t, s));
        v
    }

    /// Rebuild a queue from snapshot parts on the process-wide default
    /// backend. Entries keep their original sequence numbers, so FIFO
    /// tie-breaking — and the interleaving with post-restore pushes
    /// (which continue from `seq`) — is identical to the never-paused
    /// queue. Snapshots carry no backend marker: a snapshot written
    /// under either backend restores onto whichever is selected.
    pub fn restore(
        now: SimTime,
        seq: u64,
        entries: Vec<(SimTime, u64, E)>,
    ) -> Result<EventQueue<E>, String> {
        Self::restore_with_backend(queue_backend(), now, seq, entries)
    }

    /// [`EventQueue::restore`] onto an explicit backend.
    pub fn restore_with_backend(
        kind: QueueBackend,
        now: SimTime,
        seq: u64,
        entries: Vec<(SimTime, u64, E)>,
    ) -> Result<EventQueue<E>, String> {
        let mut q = Self::with_backend(kind);
        for (time, s, payload) in entries {
            if time < now {
                return Err(format!(
                    "event queue restore: entry at {} ns is before the clock ({} ns)",
                    time.0, now.0
                ));
            }
            if s >= seq {
                return Err(format!(
                    "event queue restore: entry seq {s} is not below the counter {seq}"
                ));
            }
            let e = Entry { time, seq: s, payload };
            match &mut q.backend {
                Backend::Heap(h) => h.push(e),
                Backend::Calendar(c) => c.insert(e),
            }
        }
        q.seq = seq;
        q.now = now;
        Ok(q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::clock::SimDuration;

    const BOTH: [QueueBackend; 2] = [QueueBackend::Calendar, QueueBackend::Heap];

    #[test]
    fn backend_names_round_trip() {
        for b in BOTH {
            assert_eq!(QueueBackend::by_name(b.name()), Some(b));
        }
        assert_eq!(QueueBackend::by_name("splay"), None);
        let q = EventQueue::<()>::with_backend(QueueBackend::Heap);
        assert_eq!(q.backend(), QueueBackend::Heap);
    }

    #[test]
    fn pops_in_time_order() {
        for b in BOTH {
            let mut q = EventQueue::with_backend(b);
            q.push(SimTime(30), "c");
            q.push(SimTime(10), "a");
            q.push(SimTime(20), "b");
            let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
            assert_eq!(order, vec!["a", "b", "c"], "{}", b.name());
        }
    }

    #[test]
    fn fifo_for_equal_times() {
        for b in BOTH {
            let mut q = EventQueue::with_backend(b);
            for i in 0..10 {
                q.push(SimTime(5), i);
            }
            let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
            assert_eq!(order, (0..10).collect::<Vec<_>>(), "{}", b.name());
        }
    }

    #[test]
    fn clock_advances() {
        for b in BOTH {
            let mut q = EventQueue::with_backend(b);
            q.push(SimTime::from_secs_f64(1.0), ());
            q.pop();
            assert!((q.now().as_secs_f64() - 1.0).abs() < 1e-9);
            // past event clamps to now
            q.push(SimTime::ZERO, ());
            let (t, _) = q.pop().unwrap();
            assert_eq!(t, q.now());
            assert!((t.as_secs_f64() - 1.0).abs() < 1e-9, "{}", b.name());
        }
    }

    #[test]
    fn advance_to_is_monotonic() {
        for b in BOTH {
            let mut q = EventQueue::<()>::with_backend(b);
            q.advance_to(SimTime(50));
            assert_eq!(q.now(), SimTime(50));
            q.advance_to(SimTime(20)); // never backwards
            assert_eq!(q.now(), SimTime(50));
            // past pushes clamp against the advanced clock
            q.push(SimTime(10), ());
            let (t, _) = q.pop().unwrap();
            assert_eq!(t, SimTime(50), "{}", b.name());
        }
    }

    #[test]
    fn pop_can_move_clock_backwards_after_advance() {
        // advance_to does not clamp entries already queued: popping one
        // of them legally moves the clock backwards. Both backends must
        // reproduce this exactly (the streamed-arrival merge loop
        // depends on it).
        for b in BOTH {
            let mut q = EventQueue::with_backend(b);
            q.push(SimTime(10), 1);
            q.advance_to(SimTime(100));
            let (t, _) = q.pop().unwrap();
            assert_eq!(t, SimTime(10), "{}", b.name());
            assert_eq!(q.now(), SimTime(10), "{}", b.name());
        }
    }

    #[test]
    fn advance_over_idle_gap_then_repopulate() {
        // An empty-queue advance over many calendar days must not
        // change observable behavior (it only fast-forwards the
        // calendar's walk floor): repopulating after the gap pops in
        // order on both backends, and past pushes clamp to the gap end.
        for b in BOTH {
            let mut q = EventQueue::with_backend(b);
            q.push(SimTime(1 << 20), 1);
            assert_eq!(q.pop().map(|(_, v)| v), Some(1));
            q.advance_to(SimTime(1 << 40)); // queue is empty across the gap
            q.push(SimTime(7), 2); // past push: clamps to the advanced clock
            q.push(SimTime((1 << 40) + 5), 3);
            let (t, v) = q.pop().unwrap();
            assert_eq!((t, v), (SimTime(1 << 40), 2), "{}", b.name());
            let (t, v) = q.pop().unwrap();
            assert_eq!((t, v), (SimTime((1 << 40) + 5), 3), "{}", b.name());
            assert!(q.pop().is_none(), "{}", b.name());
        }
    }

    #[test]
    fn snapshot_restore_preserves_pop_order_and_ties() {
        for b in BOTH {
            let mut q = EventQueue::with_backend(b);
            for i in 0..5 {
                q.push(SimTime(40), i); // equal timestamps: FIFO by seq
            }
            q.push(SimTime(10), 100);
            q.push(SimTime(20), 101);
            q.pop(); // consume the t=10 entry, clock now 10
            let entries: Vec<(SimTime, u64, i32)> =
                q.entries().into_iter().map(|(t, s, &p)| (t, s, p)).collect();
            // Restore onto BOTH backends: snapshots carry no backend
            // marker, so cross-backend resume must pop identically.
            for rb in BOTH {
                let mut restored =
                    EventQueue::restore_with_backend(rb, q.now(), q.seq(), entries.clone())
                        .unwrap();
                let mut orig =
                    EventQueue::restore_with_backend(b, q.now(), q.seq(), entries.clone())
                        .unwrap();
                // Future pushes interleave identically on both queues.
                orig.push(SimTime(40), 200);
                restored.push(SimTime(40), 200);
                let drain = |q: &mut EventQueue<i32>| -> Vec<(u64, i32)> {
                    std::iter::from_fn(|| q.pop().map(|(t, e)| (t.0, e))).collect()
                };
                assert_eq!(drain(&mut orig), drain(&mut restored), "{}→{}", b.name(), rb.name());
            }
            // A stale entry (before the clock) or seq at/over the counter
            // is refused.
            let stale = vec![(SimTime(40), 3u64, ())];
            assert!(EventQueue::restore_with_backend(b, SimTime(50), 10, stale).is_err());
            let high = vec![(SimTime(40), 2u64, ())];
            assert!(EventQueue::restore_with_backend(b, SimTime(0), 2, high).is_err());
        }
    }

    #[test]
    fn interleaved_push_pop() {
        for b in BOTH {
            let mut q = EventQueue::with_backend(b);
            q.push(SimTime(10), 1);
            let (t, v) = q.pop().unwrap();
            assert_eq!((t.0, v), (10, 1));
            q.push(t + SimDuration(5), 2);
            q.push(t + SimDuration(3), 3);
            assert_eq!(q.pop().unwrap().1, 3);
            assert_eq!(q.pop().unwrap().1, 2);
            assert!(q.is_empty(), "{}", b.name());
        }
    }

    #[test]
    fn calendar_resizes_and_stays_sorted() {
        // Enough entries to force several grow resizes (16 → 256+
        // buckets) and then shrink resizes on the way down.
        let mut q = EventQueue::with_backend(QueueBackend::Calendar);
        let mut r = crate::util::Prng::new(0xCA1E);
        for i in 0..500u64 {
            q.push(SimTime(r.gen_range(0, 5_000_000)), i);
        }
        let mut prev: Option<(SimTime, u64)> = None;
        let mut n = 0;
        while let Some((t, v)) = q.pop() {
            if let Some((pt, pv)) = prev {
                assert!((pt, pv) <= (t, v), "out of order: {pt:?} then {t:?}");
            }
            prev = Some((t, v));
            n += 1;
        }
        assert_eq!(n, 500);
    }

    #[test]
    fn calendar_handles_sparse_far_apart_times() {
        // Days far beyond one revolution exercise the fallback scan.
        let mut q = EventQueue::with_backend(QueueBackend::Calendar);
        let times = [0u64, 1, 1_000_000, 3_600_000_000_000, 7_200_000_000_000, 42];
        for (i, &t) in times.iter().enumerate() {
            q.push(SimTime(t), i);
        }
        let mut sorted: Vec<u64> = times.to_vec();
        sorted.sort_unstable();
        let mut popped = Vec::new();
        // pop clamps nothing here; collect raw times (clock moves with
        // each pop, and later pushes were already enqueued unclamped).
        while let Some((t, _)) = q.pop() {
            popped.push(t.0);
        }
        assert_eq!(popped, sorted);
    }

    #[test]
    fn calendar_matches_heap_on_random_interleaving() {
        // In-crate smoke version of the full equivalence property test
        // (rust/tests/queue_equivalence.rs drives longer sequences).
        let mut cal = EventQueue::with_backend(QueueBackend::Calendar);
        let mut heap = EventQueue::with_backend(QueueBackend::Heap);
        let mut r = crate::util::Prng::new(0xE0_0E);
        for i in 0..2000u64 {
            match r.index(4) {
                0 | 1 => {
                    let at = SimTime(cal.now().0 + r.gen_range(0, 200_000_000));
                    cal.push(at, i);
                    heap.push(at, i);
                }
                2 => {
                    assert_eq!(cal.pop(), heap.pop(), "pop diverged at op {i}");
                }
                _ => {
                    let t = SimTime(cal.now().0 + r.gen_range(0, 50_000_000));
                    cal.advance_to(t);
                    heap.advance_to(t);
                }
            }
            assert_eq!(cal.len(), heap.len());
            assert_eq!(cal.peek_time(), heap.peek_time(), "peek diverged at op {i}");
            assert_eq!(cal.now(), heap.now());
        }
        loop {
            let (a, b) = (cal.pop(), heap.pop());
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
    }
}
