//! Simulated GPU substrate: discrete-event clock/queue, device memory with
//! CUDA-VMM 2 MiB pages, interconnect + collective timing, and the
//! Table-1-calibrated instance performance model.
//!
//! Everything the paper measured on H20/A100 hosts runs here against the
//! same cost constants the paper publishes (DESIGN.md §5), so reproduced
//! comparisons preserve the paper's ratios.

pub mod clock;
pub mod comm;
pub mod engine;
pub mod event;
pub mod gpu;
pub mod link;
pub mod vmm;

pub use clock::{SimDuration, SimTime};
pub use comm::CommModel;
pub use engine::EngineModel;
pub use event::{queue_backend, set_queue_backend, EventQueue, QueueBackend};
pub use gpu::GpuDevice;
pub use link::Link;
pub use vmm::{PagePool, VmmCosts, VmmError};
