//! Interconnect timing: α–β model for NVLink / PCIe transfers.

use super::clock::SimDuration;

/// A point-to-point link with latency α and bandwidth β.
#[derive(Clone, Copy, Debug)]
pub struct Link {
    /// Per-transfer latency in microseconds.
    pub alpha_us: f64,
    /// Bandwidth in bytes per second.
    pub bw: f64,
}

impl Link {
    pub fn nvlink(bw: f64) -> Link {
        Link { alpha_us: 8.0, bw }
    }

    pub fn pcie(bw: f64) -> Link {
        Link { alpha_us: 25.0, bw }
    }

    /// Time to move `bytes` across the link.
    pub fn transfer_time(&self, bytes: u64) -> SimDuration {
        SimDuration::from_micros_f64(self.alpha_us + bytes as f64 / self.bw * 1e6)
    }

    /// Time for `n` back-to-back transfers of `bytes` each (latency paid
    /// once per transfer — models unbatched page-at-a-time copies).
    pub fn transfer_time_n(&self, n: u64, bytes: u64) -> SimDuration {
        SimDuration::from_micros_f64(
            (self.alpha_us + bytes as f64 / self.bw * 1e6) * n as f64,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bandwidth_dominates_large_transfers() {
        let l = Link::nvlink(450e9);
        let t = l.transfer_time(45_000_000_000); // 45 GB
        assert!((t.as_secs_f64() - 0.1).abs() < 0.001, "{t}");
    }

    #[test]
    fn latency_dominates_small_transfers() {
        let l = Link::nvlink(450e9);
        let t = l.transfer_time(64);
        assert!(t.as_secs_f64() > 7e-6);
    }

    #[test]
    fn batching_beats_page_at_a_time() {
        let l = Link::nvlink(450e9);
        let batched = l.transfer_time(1000 * 2 * 1024 * 1024);
        let paged = l.transfer_time_n(1000, 2 * 1024 * 1024);
        assert!(batched < paged);
    }
}
