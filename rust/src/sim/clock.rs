//! Simulated time: nanosecond-resolution monotonic clock.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in simulated time (ns since simulation start).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SimTime(pub u64);

/// A span of simulated time (ns).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SimDuration(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn from_secs_f64(s: f64) -> SimTime {
        SimTime((s.max(0.0) * 1e9) as u64)
    }

    pub fn as_secs_f64(&self) -> f64 {
        self.0 as f64 / 1e9
    }

    pub fn as_millis_f64(&self) -> f64 {
        self.0 as f64 / 1e6
    }

    /// Saturating difference.
    pub fn since(&self, earlier: SimTime) -> SimDuration {
        SimDuration(self.0.saturating_sub(earlier.0))
    }
}

impl SimDuration {
    pub const ZERO: SimDuration = SimDuration(0);

    pub fn from_secs_f64(s: f64) -> SimDuration {
        SimDuration((s.max(0.0) * 1e9) as u64)
    }

    pub fn from_millis_f64(ms: f64) -> SimDuration {
        SimDuration((ms.max(0.0) * 1e6) as u64)
    }

    pub fn from_micros_f64(us: f64) -> SimDuration {
        SimDuration((us.max(0.0) * 1e3) as u64)
    }

    pub fn as_secs_f64(&self) -> f64 {
        self.0 as f64 / 1e9
    }

    pub fn as_millis_f64(&self) -> f64 {
        self.0 as f64 / 1e6
    }

    /// Max of two durations (critical path of parallel work).
    pub fn max_of(self, other: SimDuration) -> SimDuration {
        SimDuration(self.0.max(other.0))
    }

    /// Scale by a factor.
    pub fn scale(self, f: f64) -> SimDuration {
        SimDuration((self.0 as f64 * f.max(0.0)) as u64)
    }
}

impl Add<SimDuration> for SimTime {
    type Output = SimTime;
    fn add(self, d: SimDuration) -> SimTime {
        SimTime(self.0 + d.0)
    }
}

impl AddAssign<SimDuration> for SimTime {
    fn add_assign(&mut self, d: SimDuration) {
        self.0 += d.0;
    }
}

impl Add for SimDuration {
    type Output = SimDuration;
    fn add(self, o: SimDuration) -> SimDuration {
        SimDuration(self.0 + o.0)
    }
}

impl AddAssign for SimDuration {
    fn add_assign(&mut self, o: SimDuration) {
        self.0 += o.0;
    }
}

impl Sub for SimTime {
    type Output = SimDuration;
    fn sub(self, o: SimTime) -> SimDuration {
        SimDuration(self.0.saturating_sub(o.0))
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.3}s", self.as_secs_f64())
    }
}

impl fmt::Display for SimDuration {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let ns = self.0 as f64;
        if ns < 1e3 {
            write!(f, "{ns}ns")
        } else if ns < 1e6 {
            write!(f, "{:.1}µs", ns / 1e3)
        } else if ns < 1e9 {
            write!(f, "{:.2}ms", ns / 1e6)
        } else {
            write!(f, "{:.3}s", ns / 1e9)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let t = SimTime::from_secs_f64(1.0) + SimDuration::from_millis_f64(500.0);
        assert!((t.as_secs_f64() - 1.5).abs() < 1e-9);
        let d = t - SimTime::from_secs_f64(1.0);
        assert!((d.as_millis_f64() - 500.0).abs() < 1e-6);
    }

    #[test]
    fn saturating_sub() {
        let d = SimTime::from_secs_f64(1.0) - SimTime::from_secs_f64(2.0);
        assert_eq!(d, SimDuration::ZERO);
    }

    #[test]
    fn ordering() {
        assert!(SimTime::from_secs_f64(1.0) < SimTime::from_secs_f64(2.0));
        assert_eq!(
            SimDuration::from_millis_f64(3.0).max_of(SimDuration::from_millis_f64(7.0)),
            SimDuration::from_millis_f64(7.0)
        );
    }

    #[test]
    fn display() {
        assert_eq!(format!("{}", SimDuration::from_micros_f64(1.5)), "1.5µs");
        assert_eq!(format!("{}", SimDuration::from_millis_f64(2.25)), "2.25ms");
    }
}
