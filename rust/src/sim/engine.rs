//! Instance performance model: prefill/decode step times, saturated
//! throughput, and max-supported-sequence, calibrated to the paper's
//! Table 1 (Qwen2.5-32B on H20: 448/670/767 tps, 3.75K/41.25K/120.5K).
//!
//! First-principles terms (weights/KV reads from HBM, FLOPs, all-reduce)
//! provide sensitivity to model size, batch, and context; a per-TP scale
//! factor fit once against Table 1 pins the absolute level. All other
//! experiments inherit this calibration (DESIGN.md §5).

use super::clock::SimDuration;
use super::comm::CommModel;
use crate::config::calib::{memory, table1};
use crate::config::{GpuSpec, ModelConfig};

/// Modeled decode MFU and prefill MFU (typical serving values; absolute
/// level is later absorbed by the Table-1 calibration).
const DECODE_MFU: f64 = 0.35;
const PREFILL_MFU: f64 = 0.75;
/// Reference operating point used for calibration: decode batch of 8
/// sequences at 1K context (matches the paper's 1K-token workload under
/// its TTFT/TPOT SLOs).
const CAL_BATCH: u64 = 8;
const CAL_CTX: u64 = 1000;

/// Performance model for one instance of `model` on `gpu` at TP degree tp.
#[derive(Clone, Debug)]
pub struct EngineModel {
    pub model: ModelConfig,
    pub gpu: GpuSpec,
    pub comm: CommModel,
    /// Multiplicative step-time correction per TP degree (index by log2 tp),
    /// fit so saturated decode tput matches Table 1.
    scale: [f64; 4],
    /// Memoised [`EngineModel::activation_bytes`]: depends only on the
    /// model, but the pre-memo code rebuilt the Qwen anchor
    /// `ModelConfig` on every call — and `fits()` probes capacity per
    /// routing candidate (ROADMAP hot spot). Computed once at
    /// construction; a test pins memoised == re-derived.
    act_bytes: u64,
    /// Memoised [`EngineModel::kv_capacity_bytes`] for TP 1/2/4/8 (the
    /// only degrees the transform space uses); other degrees fall back
    /// to the derivation.
    kv_caps: [u64; 4],
}

impl EngineModel {
    pub fn new(model: ModelConfig, gpu: GpuSpec) -> EngineModel {
        let mut e = Self::assemble(model, gpu);
        e.calibrate();
        e
    }

    /// Build the model with memo tables filled and unit scale (shared by
    /// [`EngineModel::new`] and the calibration anchor, which must not
    /// recurse into `calibrate`).
    fn assemble(model: ModelConfig, gpu: GpuSpec) -> EngineModel {
        let comm = CommModel::for_gpu(&gpu);
        let act_bytes = Self::derive_activation_bytes(&model);
        let kv_caps =
            [1u64, 2, 4, 8].map(|tp| Self::derive_kv_capacity_bytes(&model, &gpu, act_bytes, tp));
        EngineModel { model, gpu, comm, scale: [1.0; 4], act_bytes, kv_caps }
    }

    /// FLOPs to process one token (dense decoder: ~2 × active params).
    pub fn flops_per_token(&self) -> f64 {
        // MoE models activate a subset of experts; approximate top-2 routing.
        let m = &self.model;
        let active_experts = if m.num_experts > 1 { 2 } else { 1 };
        let mlp = match m.mlp {
            crate::config::MlpKind::Gelu => 2.0 * (m.hidden_size * m.inter_size) as f64,
            crate::config::MlpKind::SwiGlu => 3.0 * (m.hidden_size * m.inter_size) as f64,
        } * active_experts as f64;
        let attn = ((m.num_heads + 2 * m.num_kv_heads) * m.head_dim * m.hidden_size
            + m.num_heads * m.head_dim * m.hidden_size) as f64;
        2.0 * m.num_layers as f64 * (mlp + attn)
    }

    /// Raw (uncalibrated) decode step time for a batch of `batch` sequences
    /// each producing one token with average context `avg_ctx`.
    fn raw_decode_step(&self, tp: u64, batch: u64, avg_ctx: u64) -> f64 {
        let m = &self.model;
        let g = &self.gpu;
        let tpf = tp as f64;
        // Weights are re-read from HBM every step (memory-bound decode);
        // TP shards the read across workers.
        let t_weights = m.total_weight_bytes() as f64 / tpf / g.hbm_bw;
        // KV read: whole context of every sequence, sharded across workers.
        let t_kv = (batch * avg_ctx * m.kv_bytes_per_token()) as f64 / tpf / g.hbm_bw;
        // Compute (usually hidden under the memory terms at small batch).
        let t_flops = batch as f64 * self.flops_per_token() / (tpf * g.bf16_flops * DECODE_MFU);
        // Two all-reduces per layer (MHA + MLP) on batch×hidden activations.
        let act_bytes = batch * m.hidden_size * m.dtype_bytes;
        let t_ar = self.comm.allreduce(tp as u32, act_bytes).as_secs_f64()
            * 2.0
            * m.num_layers as f64;
        t_weights.max(t_flops) + t_kv + t_ar
    }

    fn scale_idx(tp: u64) -> usize {
        (63 - (tp.max(1)).leading_zeros() as usize).min(3)
    }

    /// Fit per-TP scale factors against Table 1 (Qwen2.5-32B anchors). For
    /// other models the same correction curve applies — it captures the
    /// serving-engine overheads (scheduler, kernel launches, sampling)
    /// that first-principles terms miss.
    fn calibrate(&mut self) {
        let anchor = Self::qwen_anchor();
        let anchors = [
            (1u64, table1::TPS_TP1),
            (2, table1::TPS_TP2),
            (4, table1::TPS_TP4),
        ];
        for (tp, target_tps) in anchors {
            let raw = anchor.raw_decode_step(tp, CAL_BATCH, CAL_CTX);
            let raw_tps = CAL_BATCH as f64 / raw;
            self.scale[Self::scale_idx(tp)] = raw_tps / target_tps;
        }
        // TP8: extrapolate the TP2→TP4 trend of the correction factor.
        let s2 = self.scale[1];
        let s4 = self.scale[2];
        self.scale[3] = s4 * (s4 / s2).max(1.0);
    }

    /// Decode step time (batch sequences, one token each, avg context).
    pub fn decode_step(&self, tp: u64, batch: u64, avg_ctx: u64) -> SimDuration {
        let raw = self.raw_decode_step(tp, batch.max(1), avg_ctx);
        SimDuration::from_secs_f64(raw * self.scale[Self::scale_idx(tp)])
    }

    /// Prefill time for one request of `input_len` tokens.
    pub fn prefill(&self, tp: u64, input_len: u64) -> SimDuration {
        let m = &self.model;
        let tpf = tp as f64;
        let n = input_len as f64;
        let linear = n * self.flops_per_token() / (tpf * self.gpu.bf16_flops * PREFILL_MFU);
        // Causal FlashAttention score/value matmuls: 2·n²·d per layer
        // (4·n²·d halved by the causal mask).
        let quad = 2.0 * n * n * (m.num_heads * m.head_dim) as f64 * m.num_layers as f64
            / (tpf * self.gpu.bf16_flops * PREFILL_MFU);
        // All-reduce on n×hidden activations, 2 per layer.
        let t_ar = self
            .comm
            .allreduce(tp as u32, input_len * m.hidden_size * m.dtype_bytes)
            .as_secs_f64()
            * 2.0
            * m.num_layers as f64;
        // No decode-calibration scale here: prefill is compute-bound and
        // the Table-1 correction captures decode-path serving overheads.
        SimDuration::from_secs_f64(linear + quad + t_ar)
    }

    /// Saturated decode throughput (tokens/s) at the calibration point.
    pub fn saturated_tps(&self, tp: u64) -> f64 {
        CAL_BATCH as f64 / self.decode_step(tp, CAL_BATCH, CAL_CTX).as_secs_f64()
    }

    // ------------------------------------------------------------------
    // Memory / max-sequence model
    // ------------------------------------------------------------------

    /// Total KV-cache capacity (bytes) of a TP-`tp` instance: per-GPU free
    /// memory after weights (classic full-TP sharding, as the measured
    /// Table 1 deployments use) and activations, × tp GPUs. Memoised at
    /// construction for the transform-space degrees (1/2/4/8).
    pub fn kv_capacity_bytes(&self, tp: u64) -> u64 {
        match tp {
            1 => self.kv_caps[0],
            2 => self.kv_caps[1],
            4 => self.kv_caps[2],
            8 => self.kv_caps[3],
            _ => Self::derive_kv_capacity_bytes(&self.model, &self.gpu, self.act_bytes, tp),
        }
    }

    fn derive_kv_capacity_bytes(model: &ModelConfig, gpu: &GpuSpec, act: u64, tp: u64) -> u64 {
        let w = model.worker_weight_bytes_full_tp(tp);
        let per_gpu = gpu.hbm_bytes.saturating_sub(w).saturating_sub(act);
        per_gpu * tp
    }

    /// Runtime activation reservation, scaled from the paper's Qwen/H20
    /// measurement by hidden-size ratio. Memoised at construction.
    pub fn activation_bytes(&self) -> u64 {
        self.act_bytes
    }

    fn derive_activation_bytes(model: &ModelConfig) -> u64 {
        let anchor = ModelConfig::qwen2_5_32b();
        let ratio = (model.hidden_size * model.num_layers) as f64
            / (anchor.hidden_size * anchor.num_layers) as f64;
        (memory::ACTIVATION_BYTES as f64 * ratio.min(4.0)) as u64
    }

    /// KV capacity in tokens.
    pub fn kv_capacity_tokens(&self, tp: u64) -> u64 {
        self.kv_capacity_bytes(tp) / self.model.kv_bytes_per_token()
    }

    /// Maximum supported sequence length at TP `tp`.
    ///
    /// Affine in capacity-tokens: `max_seq = a·cap + b`, with (a, b) solved
    /// from the paper's TP1/TP4 anchors for Qwen2.5-32B-on-H20; the TP2
    /// prediction then lands within ~4% of the paper's 41.25K (validated in
    /// tests). Slope < 1 reflects KV headroom reserved for the serving
    /// batch; the negative intercept reflects fixed runtime reservations.
    pub fn max_seq(&self, tp: u64) -> u64 {
        let (a, b_bytes) = Self::max_seq_coeffs();
        let cap = self.kv_capacity_tokens(tp) as f64;
        let b = b_bytes / self.model.kv_bytes_per_token() as f64;
        ((a * cap + b).max(0.0)) as u64
    }

    /// Uncalibrated Qwen-on-H20 anchor (unit scale) used by the
    /// calibration fits.
    fn qwen_anchor() -> EngineModel {
        Self::assemble(ModelConfig::qwen2_5_32b(), GpuSpec::h20())
    }

    /// Memoised `max_seq` anchor coefficients. The pair is a process-
    /// wide constant (it depends only on the fixed Qwen-on-H20 anchor),
    /// but the pre-memo code re-derived it — anchor model and all — on
    /// every `max_seq` call, and `fits()` probes `max_seq` per routing
    /// candidate (ROADMAP hot spot). One derivation per process; a test
    /// pins memoised == re-derived.
    fn max_seq_coeffs() -> (f64, f64) {
        static COEFFS: std::sync::OnceLock<(f64, f64)> = std::sync::OnceLock::new();
        *COEFFS.get_or_init(Self::derive_max_seq_coeffs)
    }

    /// Solve (a, b) from the Qwen-on-H20 anchors. b is returned in bytes
    /// so it transfers across models with different KV-per-token.
    fn derive_max_seq_coeffs() -> (f64, f64) {
        let anchor = Self::qwen_anchor();
        let c1 = anchor.kv_capacity_tokens(1) as f64;
        let c4 = anchor.kv_capacity_tokens(4) as f64;
        let s1 = table1::MAX_SEQ_TP1 as f64;
        let s4 = table1::MAX_SEQ_TP4 as f64;
        let a = (s4 - s1) / (c4 - c1);
        let b_tokens = s1 - a * c1;
        (a, b_tokens * anchor.model.kv_bytes_per_token() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn qwen_h20() -> EngineModel {
        EngineModel::new(ModelConfig::qwen2_5_32b(), GpuSpec::h20())
    }

    #[test]
    fn table1_throughput_anchors_reproduced() {
        let e = qwen_h20();
        for (tp, paper) in [(1u64, 448.0), (2, 670.0), (4, 767.0)] {
            let tps = e.saturated_tps(tp);
            assert!(
                (tps - paper).abs() / paper < 0.01,
                "tp{tp}: {tps} vs paper {paper}"
            );
        }
    }

    #[test]
    fn table1_max_seq_anchors_reproduced() {
        let e = qwen_h20();
        // TP1 and TP4 are exact by construction.
        let s1 = e.max_seq(1) as f64;
        let s4 = e.max_seq(4) as f64;
        assert!((s1 - 3750.0).abs() / 3750.0 < 0.01, "tp1 {s1}");
        assert!((s4 - 120_500.0).abs() / 120_500.0 < 0.01, "tp4 {s4}");
        // TP2 is a *prediction* — paper says 41.25K; accept ±10%.
        let s2 = e.max_seq(2) as f64;
        assert!((s2 - 41_250.0).abs() / 41_250.0 < 0.10, "tp2 {s2}");
    }

    #[test]
    fn throughput_loss_tp4_exceeds_57pct() {
        let e = qwen_h20();
        let loss = 1.0 - e.saturated_tps(4) / (4.0 * e.saturated_tps(1));
        assert!(loss > 0.57, "loss {loss}");
    }

    #[test]
    fn decode_step_monotonic_in_batch_and_ctx() {
        let e = qwen_h20();
        assert!(e.decode_step(1, 16, 1000) > e.decode_step(1, 8, 1000));
        assert!(e.decode_step(1, 8, 4000) > e.decode_step(1, 8, 500));
    }

    #[test]
    fn prefill_superlinear_in_length() {
        let e = qwen_h20();
        let t1 = e.prefill(4, 10_000).as_secs_f64();
        let t2 = e.prefill(4, 50_000).as_secs_f64();
        assert!(t2 > 5.0 * t1, "t1={t1} t2={t2}");
        // 50K prefill on TP4 should be near the paper's 10 s TTFT SLO edge.
        assert!(t2 > 2.0 && t2 < 15.0, "t2={t2}");
    }

    #[test]
    fn prefill_speeds_up_with_tp() {
        let e = qwen_h20();
        assert!(e.prefill(4, 20_000) < e.prefill(2, 20_000));
    }

    #[test]
    fn kv_capacity_grows_with_tp() {
        let e = qwen_h20();
        assert!(e.kv_capacity_bytes(4) > e.kv_capacity_bytes(2));
        assert!(e.kv_capacity_bytes(2) > e.kv_capacity_bytes(1));
    }

    #[test]
    fn smaller_model_has_higher_tput() {
        let small = EngineModel::new(ModelConfig::llama2_7b(), GpuSpec::a100_40g());
        let big = qwen_h20();
        assert!(small.saturated_tps(1) > big.saturated_tps(1));
    }

    #[test]
    fn memoised_max_seq_coeffs_match_rederived() {
        // The process-wide memo must be bit-identical to a fresh
        // derivation...
        let (a, b_bytes) = EngineModel::derive_max_seq_coeffs();
        assert_eq!(EngineModel::max_seq_coeffs(), (a, b_bytes));
        // ...and max_seq must equal the formula applied to re-derived
        // coefficients, for every model and TP degree.
        for m in ModelConfig::all() {
            let gpu = GpuSpec::for_model(&m);
            let e = EngineModel::new(m, gpu);
            for tp in [1u64, 2, 4, 8] {
                let cap = e.kv_capacity_tokens(tp) as f64;
                let b = b_bytes / e.model.kv_bytes_per_token() as f64;
                let expect = ((a * cap + b).max(0.0)) as u64;
                assert_eq!(e.max_seq(tp), expect, "{} tp{tp}", e.model.name);
            }
        }
    }

    #[test]
    fn memoised_capacity_matches_rederived() {
        // activation_bytes / kv_capacity_bytes are filled once at
        // construction; they must equal a fresh derivation for every
        // model, both on the memoised TP degrees (1/2/4/8) and on the
        // fallback path (tp=3 here).
        for m in ModelConfig::all() {
            let gpu = GpuSpec::for_model(&m);
            let e = EngineModel::new(m, gpu);
            assert_eq!(
                e.activation_bytes(),
                EngineModel::derive_activation_bytes(&e.model),
                "{} activation_bytes",
                e.model.name
            );
            for tp in [1u64, 2, 3, 4, 8] {
                let expect = EngineModel::derive_kv_capacity_bytes(
                    &e.model,
                    &e.gpu,
                    e.activation_bytes(),
                    tp,
                );
                assert_eq!(e.kv_capacity_bytes(tp), expect, "{} tp{tp}", e.model.name);
            }
        }
    }

    #[test]
    fn max_seq_nonnegative_for_all_models() {
        for m in ModelConfig::all() {
            let gpu = GpuSpec::for_model(&m);
            let e = EngineModel::new(m, gpu);
            for tp in [1, 2, 4] {
                let _ = e.max_seq(tp); // must not panic/underflow
            }
        }
    }
}
