//! Per-device state: page pool plus reserved weight/activation regions.

use super::vmm::{PagePool, VmmError};
use crate::config::GpuSpec;
use crate::util::bytes::VMM_PAGE;

/// One simulated GPU: 2 MiB-page pool with named reservations.
#[derive(Clone, Debug)]
pub struct GpuDevice {
    pub spec: GpuSpec,
    pub pool: PagePool,
    weight_pages: Vec<u64>,
    activation_pages: Vec<u64>,
}

impl GpuDevice {
    pub fn new(spec: GpuSpec) -> GpuDevice {
        let pool = PagePool::new(spec.hbm_bytes);
        GpuDevice { spec, pool, weight_pages: Vec::new(), activation_pages: Vec::new() }
    }

    /// Commit the model-weight region (bytes rounded up to pages).
    pub fn reserve_weights(&mut self, bytes: u64) -> Result<(), VmmError> {
        assert!(self.weight_pages.is_empty(), "weights already reserved");
        self.weight_pages = self.pool.alloc_bytes(bytes)?;
        Ok(())
    }

    /// Replace the weight reservation with a smaller/larger one, returning
    /// (pages_released, pages_added). Used by weight transformation.
    pub fn resize_weights(&mut self, new_bytes: u64) -> Result<(i64, i64), VmmError> {
        let new_pages = new_bytes.div_ceil(VMM_PAGE);
        let cur = self.weight_pages.len() as u64;
        if new_pages < cur {
            let n_release = (cur - new_pages) as usize;
            let released: Vec<u64> =
                self.weight_pages.drain(self.weight_pages.len() - n_release..).collect();
            self.pool.release(&released)?;
            Ok((n_release as i64, 0))
        } else if new_pages > cur {
            let extra = self.pool.alloc(new_pages - cur)?;
            let n = extra.len() as i64;
            self.weight_pages.extend(extra);
            Ok((0, n))
        } else {
            Ok((0, 0))
        }
    }

    /// Commit the runtime-activation region.
    pub fn reserve_activations(&mut self, bytes: u64) -> Result<(), VmmError> {
        assert!(self.activation_pages.is_empty(), "activations already reserved");
        self.activation_pages = self.pool.alloc_bytes(bytes)?;
        Ok(())
    }

    pub fn weight_bytes(&self) -> u64 {
        self.weight_pages.len() as u64 * VMM_PAGE
    }

    /// Bytes left for the KV cache (and transformation scratch).
    pub fn free_bytes(&self) -> u64 {
        self.pool.free_pages() * VMM_PAGE
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::calib::memory;
    use crate::config::ModelConfig;

    #[test]
    fn h20_qwen_memory_budget() {
        let mut g = GpuDevice::new(GpuSpec::h20());
        g.reserve_weights(ModelConfig::qwen2_5_32b().total_weight_bytes()).unwrap();
        g.reserve_activations(memory::ACTIVATION_BYTES).unwrap();
        // Remaining KV space must be positive and below total.
        let free = g.free_bytes();
        assert!(free > 10_000_000_000, "free={free}");
        assert!(free < g.spec.hbm_bytes);
    }

    #[test]
    fn resize_weights_releases_pages() {
        let mut g = GpuDevice::new(GpuSpec::h20());
        g.reserve_weights(40 * crate::util::GIB).unwrap();
        let before = g.free_bytes();
        let (released, added) = g.resize_weights(10 * crate::util::GIB).unwrap();
        assert!(released > 0 && added == 0);
        assert!(g.free_bytes() > before);
        let (released2, added2) = g.resize_weights(20 * crate::util::GIB).unwrap();
        assert!(released2 == 0 && added2 > 0);
    }

    #[test]
    fn cannot_over_reserve() {
        let mut g = GpuDevice::new(GpuSpec::a100_40g());
        assert!(g.reserve_weights(100 * crate::util::GIB).is_err());
    }
}
