//! # Gyges — Dynamic Cross-Instance Parallelism Transformation
//!
//! Reproduction of *Gyges: Dynamic Cross-Instance Parallelism
//! Transformation for Efficient LLM Inference* (cs.DC 2025) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! - **Layer 3 (this crate)** — the serving coordinator: page-friendly
//!   header-centric KV-cache management ([`kvcache`]), parallelism-aware
//!   weight padding and in-place transformation ([`weights`]),
//!   layer-staggered hybrid transformation ([`transform`]), and the
//!   transformation-aware scheduler ([`coordinator`]) with RR/LLF and
//!   Seesaw/KunServe/LoongServe [`baselines`] — all running over a
//!   calibrated GPU-cluster substrate ([`sim`]).
//! - **Layer 2/1 (python/)** — the JAX transformer model and Pallas
//!   kernels, AOT-lowered to HLO text artifacts executed from the Rust
//!   request path via PJRT ([`runtime`]).
//!
//! See DESIGN.md for the system inventory and the per-experiment index,
//! and EXPERIMENTS.md for paper-vs-measured results.

// The crate is pure safe Rust and must stay that way: every
// equivalence proof (serial==parallel, shard-merge, kill/resume, ...)
// assumes no hidden aliasing or uninitialised bytes. The deny set is
// curated, not `warnings`: CI's clippy job already gates on warnings,
// while these are the contract-level lints that must hold even in
// local feature-gated builds.
#![forbid(unsafe_code)]
#![deny(non_ascii_idents, unused_extern_crates, unused_must_use)]

pub mod analysis;
pub mod baselines;
pub mod cache;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod faults;
pub mod metrics;
pub mod transform;
pub mod workload;
pub mod kvcache;
pub mod weights;
#[cfg(feature = "pjrt")]
pub mod runtime;
#[cfg(feature = "pjrt")]
pub mod serve;
pub mod sim;
pub mod snapshot;
pub mod util;

pub use config::{ClusterConfig, GpuSpec, ModelConfig, Policy};
pub use sim::{EngineModel, SimDuration, SimTime};
