//! Deterministic per-instance prefix-cache model (approximate radix tree
//! + LRU), the simulator-side analogue of SGLang's cache-aware router
//! state (SNIPPETS.md Snippet 1) and of Infinite-LLM's view of KV
//! capacity as the contended resource a prefix cache is evicted against.
//!
//! The model is intentionally approximate: requests carry an ordered
//! path of seeded *prefix-block ids* (each block standing for
//! `block_tokens` prompt tokens, see `workload::PrefixMix`), and each
//! instance owns a radix tree over those block ids. On assignment the
//! request's path is matched against the tree (matched blocks = cache
//! hit, shortening the modeled prefill) and the unmatched tail is
//! inserted; the tree is leaf-LRU-evicted against the instance's KV
//! capacity expressed in blocks. Transformations, host crashes, and
//! transform aborts invalidate the affected instances' trees — the
//! locality cost of a Gyges transformation that no throughput counter
//! captures on its own.
//!
//! Determinism contract: every structure is ordered (slab `Vec` +
//! `BTreeMap` edges + `BTreeSet` LRU), eviction order is the total order
//! `(last_access_ns, touch_seq, slot)`, and all state round-trips
//! through snapshots byte-exactly (slot indices and the free-list order
//! are preserved because they participate in eviction tie-breaks).
//! When the cache is disarmed (`ClusterSim` holds no `ClusterCache`)
//! nothing here executes, so every pre-existing figure stays
//! byte-identical.

use crate::sim::clock::SimTime;
use crate::util::json::Json;
use std::collections::{BTreeMap, BTreeSet};

/// Prompt tokens represented by one prefix block. 128 tokens mirrors
/// common paged-KV block sizing and keeps fig-cache trees small enough
/// to walk per-request without showing up in profiles.
pub const DEFAULT_BLOCK_TOKENS: u64 = 128;

/// Sentinel parent for depth-0 nodes (the implicit root is not stored).
const ROOT: u32 = u32::MAX;

/// One radix-tree node: a single prefix block cached on the instance.
#[derive(Clone, Debug, PartialEq, Eq)]
struct Node {
    parent: u32,
    block: u64,
    /// Live child count; 0 ⇒ the node is a leaf and sits in the LRU set.
    children: u32,
    last_access: u64,
    /// Monotone per-tree touch counter breaking same-timestamp LRU ties.
    seq: u64,
    live: bool,
}

/// What one `match_and_insert` call did, in blocks.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheOutcome {
    pub matched: u64,
    pub inserted: u64,
    pub evicted: u64,
}

/// Approximate radix tree over prefix-block ids for one instance.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PrefixTree {
    /// Node slab; slot indices are stable across eviction (free list)
    /// and across snapshot/resume (they break LRU ties).
    nodes: Vec<Node>,
    /// Free slots, popped LIFO on insert.
    free: Vec<u32>,
    /// `(parent_slot, block_id) -> child_slot` for live nodes.
    edges: BTreeMap<(u32, u64), u32>,
    /// Live leaves ordered `(last_access, seq, slot)` — the LRU order.
    lru: BTreeSet<(u64, u64, u32)>,
    /// Live node count (= cached blocks).
    size: u64,
    seq: u64,
}

impl PrefixTree {
    pub fn new() -> PrefixTree {
        PrefixTree::default()
    }

    /// Cached blocks currently live.
    pub fn len(&self) -> u64 {
        self.size
    }

    pub fn is_empty(&self) -> bool {
        self.size == 0
    }

    /// Longest cached prefix of `path`, in blocks. Read-only: no LRU
    /// touch — routing probes every candidate instance and must not
    /// perturb eviction order for instances it does not pick.
    pub fn match_len(&self, path: &[u64]) -> u64 {
        let mut parent = ROOT;
        let mut matched = 0u64;
        for &block in path {
            match self.edges.get(&(parent, block)) {
                Some(&child) => {
                    parent = child;
                    matched += 1;
                }
                None => break,
            }
        }
        matched
    }

    /// Match `path` against the tree (touching matched nodes), insert
    /// the unmatched tail, then LRU-evict leaves until at most
    /// `cap_blocks` blocks remain.
    pub fn match_and_insert(&mut self, path: &[u64], now: SimTime, cap_blocks: u64) -> CacheOutcome {
        let mut out = CacheOutcome::default();
        let mut parent = ROOT;
        let mut i = 0usize;
        while i < path.len() {
            match self.edges.get(&(parent, path[i])).copied() {
                Some(child) => {
                    self.touch(child, now.0);
                    parent = child;
                    out.matched += 1;
                    i += 1;
                }
                None => break,
            }
        }
        while i < path.len() {
            parent = self.alloc(parent, path[i], now.0);
            out.inserted += 1;
            i += 1;
        }
        out.evicted = self.evict_to(cap_blocks);
        out
    }

    /// Drop every cached block (transformation / crash / abort).
    /// Returns the number of blocks invalidated.
    pub fn clear(&mut self) -> u64 {
        let dropped = self.size;
        self.nodes.clear();
        self.free.clear();
        self.edges.clear();
        self.lru.clear();
        self.size = 0;
        // `seq` deliberately survives: slot indices restart but the
        // touch order stays globally monotone within the tree.
        dropped
    }

    /// Refresh a node's LRU stamp, maintaining the leaf set.
    fn touch(&mut self, idx: u32, now_ns: u64) {
        self.seq += 1;
        let seq = self.seq;
        let n = &mut self.nodes[idx as usize];
        if n.children == 0 {
            self.lru.remove(&(n.last_access, n.seq, idx));
            self.lru.insert((now_ns, seq, idx));
        }
        n.last_access = now_ns;
        n.seq = seq;
    }

    /// Insert a fresh leaf under `parent`, returning its slot.
    fn alloc(&mut self, parent: u32, block: u64, now_ns: u64) -> u32 {
        self.seq += 1;
        let node = Node {
            parent,
            block,
            children: 0,
            last_access: now_ns,
            seq: self.seq,
            live: true,
        };
        let idx = match self.free.pop() {
            Some(slot) => {
                self.nodes[slot as usize] = node;
                slot
            }
            None => {
                self.nodes.push(node);
                (self.nodes.len() - 1) as u32
            }
        };
        self.edges.insert((parent, block), idx);
        self.lru.insert((now_ns, self.seq, idx));
        if parent != ROOT {
            let p = &mut self.nodes[parent as usize];
            p.children += 1;
            if p.children == 1 {
                // Parent just stopped being a leaf.
                self.lru.remove(&(p.last_access, p.seq, parent));
            }
        }
        self.size += 1;
        idx
    }

    /// Evict least-recently-used leaves until `size <= cap_blocks`.
    fn evict_to(&mut self, cap_blocks: u64) -> u64 {
        let mut evicted = 0u64;
        while self.size > cap_blocks {
            let Some(&key) = self.lru.iter().next() else { break };
            self.lru.remove(&key);
            let idx = key.2;
            let (parent, block) = {
                let n = &mut self.nodes[idx as usize];
                n.live = false;
                (n.parent, n.block)
            };
            self.edges.remove(&(parent, block));
            self.free.push(idx);
            self.size -= 1;
            evicted += 1;
            if parent != ROOT {
                let p = &mut self.nodes[parent as usize];
                p.children -= 1;
                if p.children == 0 {
                    self.lru.insert((p.last_access, p.seq, parent));
                }
            }
        }
        evicted
    }

    /// Order- and state-sensitive fingerprint (slots, stamps, free-list
    /// order): two trees fingerprint equal iff their future behaviour
    /// is identical.
    pub fn fingerprint(&self) -> u64 {
        let mut bytes = Vec::with_capacity(16 + self.edges.len() * 40);
        bytes.extend_from_slice(&self.seq.to_le_bytes());
        bytes.extend_from_slice(&self.size.to_le_bytes());
        for (&(parent, block), &idx) in &self.edges {
            let n = &self.nodes[idx as usize];
            for w in [parent as u64, block, idx as u64, n.last_access, n.seq] {
                bytes.extend_from_slice(&w.to_le_bytes());
            }
        }
        for &slot in &self.free {
            bytes.extend_from_slice(&(slot as u64).to_le_bytes());
        }
        crate::util::hash::fnv1a(&bytes)
    }

    /// Snapshot codec: the full slab (dead slots as `null`) plus the
    /// free-list order — both participate in eviction tie-breaks, so a
    /// resumed tree must reproduce them exactly.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("seq", self.seq);
        let nodes: Vec<Json> = self
            .nodes
            .iter()
            .map(|n| {
                if !n.live {
                    return Json::Null;
                }
                let mut e = Json::obj();
                e.set("parent", n.parent as u64)
                    .set("block", n.block)
                    .set("last", n.last_access)
                    .set("seq", n.seq);
                e
            })
            .collect();
        o.set("nodes", Json::Arr(nodes));
        o.set("free", Json::Arr(self.free.iter().map(|&s| Json::from(s as u64)).collect()));
        o
    }

    /// Rebuild from [`PrefixTree::to_json`]; edges, leaf set, child
    /// counts, and size are recomputed from the live slab (they are
    /// defined by it).
    pub fn from_json(v: &Json) -> Result<PrefixTree, String> {
        let ctx = "prefix tree";
        let mut t = PrefixTree {
            seq: v.req_u64("seq", ctx)?,
            ..PrefixTree::default()
        };
        for slot in v.req_arr("nodes", ctx)? {
            if matches!(slot, Json::Null) {
                t.nodes.push(Node {
                    parent: ROOT,
                    block: 0,
                    children: 0,
                    last_access: 0,
                    seq: 0,
                    live: false,
                });
                continue;
            }
            let parent = slot.req_u64("parent", ctx)?;
            if parent > ROOT as u64 {
                return Err(format!("{ctx}: parent {parent} out of range"));
            }
            t.nodes.push(Node {
                parent: parent as u32,
                block: slot.req_u64("block", ctx)?,
                children: 0,
                last_access: slot.req_u64("last", ctx)?,
                seq: slot.req_u64("seq", ctx)?,
                live: true,
            });
        }
        for f in v.req_arr("free", ctx)? {
            let slot = f.as_u64().ok_or_else(|| format!("{ctx}: bad free slot"))?;
            if slot as usize >= t.nodes.len() {
                return Err(format!("{ctx}: free slot {slot} out of range"));
            }
            t.free.push(slot as u32);
        }
        // Recompute the derived structures from the live slab.
        for (i, n) in t.nodes.iter().enumerate() {
            if !n.live {
                continue;
            }
            t.edges.insert((n.parent, n.block), i as u32);
            t.size += 1;
        }
        let mut children: Vec<u32> = vec![0; t.nodes.len()];
        for n in t.nodes.iter().filter(|n| n.live && n.parent != ROOT) {
            if n.parent as usize >= t.nodes.len() || !t.nodes[n.parent as usize].live {
                return Err(format!("{ctx}: dangling parent {}", n.parent));
            }
            children[n.parent as usize] += 1;
        }
        for (i, n) in t.nodes.iter_mut().enumerate() {
            n.children = children[i];
            if n.live && n.children == 0 {
                t.lru.insert((n.last_access, n.seq, i as u32));
            }
        }
        if t.edges.len() as u64 != t.size {
            return Err(format!("{ctx}: duplicate (parent, block) edges"));
        }
        Ok(t)
    }
}

/// Cluster-wide cache activity counters. These live OUTSIDE
/// `SimCounters` on purpose: sweep rows serialize every `SimCounters`
/// field unconditionally, so cache counters must be armed-only
/// (encoding-as-absence) to keep pre-cache sweep artifacts
/// byte-identical.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheCounters {
    /// Routed requests that carried a non-empty prefix path.
    pub lookups: u64,
    pub hit_blocks: u64,
    pub miss_blocks: u64,
    pub inserted_blocks: u64,
    pub evicted_blocks: u64,
    /// Tree clears caused by transformation / crash / abort (counted
    /// only when the tree held at least one block).
    pub invalidations: u64,
}

impl CacheCounters {
    /// Block-level hit rate over prefixed lookups.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hit_blocks + self.miss_blocks;
        if total == 0 {
            0.0
        } else {
            self.hit_blocks as f64 / total as f64
        }
    }
}

/// Per-instance prefix trees plus cluster-wide counters — the armed
/// (opt-in) cache state a `ClusterSim` carries.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClusterCache {
    pub block_tokens: u64,
    /// Indexed by instance id; `None` = retired or never-assigned.
    trees: Vec<Option<PrefixTree>>,
    pub counters: CacheCounters,
}

impl ClusterCache {
    pub fn new(block_tokens: u64) -> ClusterCache {
        ClusterCache {
            block_tokens: block_tokens.max(1),
            trees: Vec::new(),
            counters: CacheCounters::default(),
        }
    }

    /// KV capacity in blocks for a capacity in tokens.
    pub fn cap_blocks(&self, cap_tokens: u64) -> u64 {
        cap_tokens / self.block_tokens
    }

    /// Record an assignment of a prefixed request to instance `iid`:
    /// match + insert + evict on its tree, update the cluster counters,
    /// and return the matched (cache-hit) token count. Prefix-free
    /// requests are a no-op so plain workloads never dilute hit-rate.
    pub fn observe(&mut self, iid: usize, path: &[u64], now: SimTime, cap_tokens: u64) -> u64 {
        if path.is_empty() {
            return 0;
        }
        if self.trees.len() <= iid {
            self.trees.resize_with(iid + 1, || None);
        }
        let cap = self.cap_blocks(cap_tokens);
        let tree = self.trees[iid].get_or_insert_with(PrefixTree::new);
        let out = tree.match_and_insert(path, now, cap);
        self.counters.lookups += 1;
        self.counters.hit_blocks += out.matched;
        self.counters.miss_blocks += out.inserted;
        self.counters.inserted_blocks += out.inserted;
        self.counters.evicted_blocks += out.evicted;
        out.matched * self.block_tokens
    }

    /// Read-only matched fraction of `path` on `iid` (the routing
    /// affinity signal): 0.0 when the path is empty or no tree exists.
    pub fn match_fraction(&self, iid: usize, path: &[u64]) -> f64 {
        if path.is_empty() {
            return 0.0;
        }
        match self.trees.get(iid).and_then(|t| t.as_ref()) {
            Some(tree) => tree.match_len(path) as f64 / path.len() as f64,
            None => 0.0,
        }
    }

    /// Drop instance `iid`'s cached blocks (transformation split/merge,
    /// host crash, transform abort). Keeps the slot so a later
    /// assignment restarts cold.
    pub fn invalidate(&mut self, iid: usize) {
        if let Some(Some(tree)) = self.trees.get_mut(iid) {
            if tree.clear() > 0 {
                self.counters.invalidations += 1;
            }
        }
    }

    /// Invalidate and drop the slot (instance retired for good).
    pub fn retire(&mut self, iid: usize) {
        self.invalidate(iid);
        if let Some(slot) = self.trees.get_mut(iid) {
            *slot = None;
        }
    }

    /// Blocks currently cached on `iid`.
    pub fn cached_blocks(&self, iid: usize) -> u64 {
        self.trees.get(iid).and_then(|t| t.as_ref()).map_or(0, |t| t.len())
    }

    /// Deterministic whole-cache fingerprint (tests / divergence checks).
    pub fn fingerprint(&self) -> u64 {
        let mut bytes = Vec::with_capacity(64 + self.trees.len() * 8);
        for c in [
            self.block_tokens,
            self.counters.lookups,
            self.counters.hit_blocks,
            self.counters.miss_blocks,
            self.counters.inserted_blocks,
            self.counters.evicted_blocks,
            self.counters.invalidations,
        ] {
            bytes.extend_from_slice(&c.to_le_bytes());
        }
        for t in &self.trees {
            let f = t.as_ref().map_or(0, |t| t.fingerprint());
            bytes.extend_from_slice(&f.to_le_bytes());
        }
        crate::util::hash::fnv1a(&bytes)
    }

    /// Snapshot codec (schema v5 `cache` payload).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("block_tokens", self.block_tokens);
        let mut c = Json::obj();
        c.set("lookups", self.counters.lookups)
            .set("hit_blocks", self.counters.hit_blocks)
            .set("miss_blocks", self.counters.miss_blocks)
            .set("inserted_blocks", self.counters.inserted_blocks)
            .set("evicted_blocks", self.counters.evicted_blocks)
            .set("invalidations", self.counters.invalidations);
        o.set("counters", c);
        o.set(
            "trees",
            Json::Arr(
                self.trees
                    .iter()
                    .map(|t| t.as_ref().map_or(Json::Null, |t| t.to_json()))
                    .collect(),
            ),
        );
        o
    }

    pub fn from_json(v: &Json) -> Result<ClusterCache, String> {
        let ctx = "cache";
        let c = v.get("counters").ok_or_else(|| format!("{ctx}: missing counters"))?;
        let mut cache = ClusterCache::new(v.req_u64("block_tokens", ctx)?);
        cache.counters = CacheCounters {
            lookups: c.req_u64("lookups", ctx)?,
            hit_blocks: c.req_u64("hit_blocks", ctx)?,
            miss_blocks: c.req_u64("miss_blocks", ctx)?,
            inserted_blocks: c.req_u64("inserted_blocks", ctx)?,
            evicted_blocks: c.req_u64("evicted_blocks", ctx)?,
            invalidations: c.req_u64("invalidations", ctx)?,
        };
        for t in v.req_arr("trees", ctx)? {
            cache.trees.push(match t {
                Json::Null => None,
                other => Some(PrefixTree::from_json(other)?),
            });
        }
        Ok(cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn at(s: f64) -> SimTime {
        SimTime::from_secs_f64(s)
    }

    #[test]
    fn match_grows_with_shared_prefix() {
        let mut t = PrefixTree::new();
        let out = t.match_and_insert(&[1, 2, 3], at(1.0), 100);
        assert_eq!(out, CacheOutcome { matched: 0, inserted: 3, evicted: 0 });
        let out = t.match_and_insert(&[1, 2, 9], at(2.0), 100);
        assert_eq!(out, CacheOutcome { matched: 2, inserted: 1, evicted: 0 });
        assert_eq!(t.match_len(&[1, 2, 3]), 3);
        assert_eq!(t.match_len(&[1, 2, 9, 7]), 3);
        assert_eq!(t.match_len(&[5]), 0);
        assert_eq!(t.len(), 4);
    }

    #[test]
    fn lru_evicts_oldest_leaf_first() {
        let mut t = PrefixTree::new();
        t.match_and_insert(&[1, 2], at(1.0), 100);
        t.match_and_insert(&[3, 4], at(2.0), 100);
        // Cap 3: the oldest leaf (node 2's slot, stamped at 1.0) goes.
        let out = t.match_and_insert(&[5], at(3.0), 3);
        assert_eq!(out.evicted, 2, "leaf then its newly-leafed parent");
        assert_eq!(t.match_len(&[1, 2]), 0, "old chain evicted");
        assert_eq!(t.match_len(&[3, 4]), 2, "newer chain survives");
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn touch_protects_recently_matched_chain() {
        let mut t = PrefixTree::new();
        t.match_and_insert(&[1, 2], at(1.0), 100);
        t.match_and_insert(&[3, 4], at(2.0), 100);
        // Re-touch the old chain, then force one eviction: the
        // untouched chain (3,4) is now the LRU victim.
        t.match_and_insert(&[1, 2], at(3.0), 100);
        t.match_and_insert(&[5], at(4.0), 3);
        assert_eq!(t.match_len(&[1, 2]), 2);
        assert_eq!(t.match_len(&[3, 4]), 0);
    }

    #[test]
    fn inner_nodes_are_not_evictable() {
        let mut t = PrefixTree::new();
        t.match_and_insert(&[1], at(1.0), 100);
        t.match_and_insert(&[1, 2], at(2.0), 100);
        // Node 1 is old but has a child; only the leaf 2 is evictable.
        t.match_and_insert(&[9], at(3.0), 2);
        assert_eq!(t.len(), 2);
        assert_eq!(t.match_len(&[1]), 1, "inner node survives");
        assert_eq!(t.match_len(&[9]), 1);
    }

    #[test]
    fn clear_resets_but_seq_survives() {
        let mut t = PrefixTree::new();
        t.match_and_insert(&[1, 2, 3], at(1.0), 100);
        assert_eq!(t.clear(), 3);
        assert!(t.is_empty());
        assert_eq!(t.match_len(&[1]), 0);
        let out = t.match_and_insert(&[1], at(2.0), 100);
        assert_eq!(out.inserted, 1);
    }

    #[test]
    fn slot_reuse_is_lifo_and_fingerprinted() {
        let mut t = PrefixTree::new();
        t.match_and_insert(&[1], at(1.0), 100);
        t.match_and_insert(&[2], at(2.0), 100);
        let f1 = t.fingerprint();
        t.match_and_insert(&[3], at(3.0), 2); // evicts slot of block 1
        assert_ne!(t.fingerprint(), f1, "fingerprint tracks state");
        let json = t.to_json();
        let back = PrefixTree::from_json(&json).unwrap();
        assert_eq!(back.fingerprint(), t.fingerprint(), "snapshot exact");
    }

    #[test]
    fn snapshot_roundtrip_preserves_future_evictions() {
        let mut a = PrefixTree::new();
        for (i, path) in [[1u64, 2].as_slice(), &[1, 3], &[4, 5], &[6]].iter().enumerate() {
            a.match_and_insert(path, at(i as f64), 100);
        }
        a.match_and_insert(&[7], at(10.0), 4); // force evictions + free slots
        let mut b = PrefixTree::from_json(&a.to_json()).unwrap();
        assert_eq!(a.fingerprint(), b.fingerprint());
        // Same op on both sides must stay identical (free-list order,
        // LRU ties, slot ids all preserved).
        let oa = a.match_and_insert(&[8, 9], at(11.0), 4);
        let ob = b.match_and_insert(&[8, 9], at(11.0), 4);
        assert_eq!(oa, ob);
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn from_json_rejects_corrupt_trees() {
        assert!(PrefixTree::from_json(&Json::parse(r#"{"seq": 1}"#).unwrap()).is_err());
        // dangling parent
        let bad = r#"{"seq":2,"nodes":[{"parent":7,"block":1,"last":0,"seq":1}],"free":[]}"#;
        assert!(PrefixTree::from_json(&Json::parse(bad).unwrap()).is_err());
        // free slot out of range
        let bad = r#"{"seq":1,"nodes":[],"free":[3]}"#;
        assert!(PrefixTree::from_json(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn cluster_cache_counters_and_affinity() {
        let mut c = ClusterCache::new(100);
        assert_eq!(c.observe(0, &[], at(1.0), 10_000), 0, "prefix-free is a no-op");
        assert_eq!(c.counters.lookups, 0);
        assert_eq!(c.observe(0, &[1, 2], at(1.0), 10_000), 0, "cold miss");
        assert_eq!(c.observe(0, &[1, 2], at(2.0), 10_000), 200, "warm hit");
        assert_eq!(c.counters.lookups, 2);
        assert_eq!(c.counters.hit_blocks, 2);
        assert_eq!(c.counters.miss_blocks, 2);
        assert!((c.counters.hit_rate() - 0.5).abs() < 1e-12);
        assert!((c.match_fraction(0, &[1, 2, 3]) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(c.match_fraction(1, &[1, 2]), 0.0, "unknown instance is cold");
        c.invalidate(0);
        assert_eq!(c.counters.invalidations, 1);
        c.invalidate(0);
        assert_eq!(c.counters.invalidations, 1, "empty clear not counted");
        assert_eq!(c.match_fraction(0, &[1, 2]), 0.0);
    }

    #[test]
    fn cluster_cache_snapshot_roundtrip() {
        let mut c = ClusterCache::new(DEFAULT_BLOCK_TOKENS);
        c.observe(0, &[1, 2, 3], at(1.0), 1 << 20);
        c.observe(2, &[1, 9], at(2.0), 1 << 20);
        c.retire(1);
        c.invalidate(0);
        let back = ClusterCache::from_json(&c.to_json()).unwrap();
        assert_eq!(back.fingerprint(), c.fingerprint());
        assert_eq!(back.counters, c.counters);
        assert_eq!(back.cached_blocks(2), 2);
    }

    #[test]
    fn capacity_in_blocks_floors() {
        let c = ClusterCache::new(128);
        assert_eq!(c.cap_blocks(1000), 7);
        assert_eq!(c.cap_blocks(127), 0);
        let z = ClusterCache::new(0);
        assert_eq!(z.block_tokens, 1, "block size clamps to 1");
    }
}
