"""AOT export sanity: HLO text artifacts, weight binaries, manifest and
oracle are complete and well-formed."""

import json
import os

import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def outdir(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("artifacts"))
    aot.export_all(d)
    return d


def test_all_modules_emitted(outdir):
    expected = ["embed", "lm_head"] + [
        f"{kind}_tp{tp}"
        for kind in ("qkv", "kvupd", "attnout", "mlp")
        for tp in model.TP_CHOICES
    ]
    for name in expected:
        path = os.path.join(outdir, f"{name}.hlo.txt")
        assert os.path.exists(path), name
        text = open(path).read()
        assert "ENTRY" in text, f"{name}: not HLO text"
        assert "custom-call" not in text, f"{name}: Mosaic custom-call leaked"


def test_manifest_consistent(outdir):
    man = json.load(open(os.path.join(outdir, "manifest.json")))
    assert man["hidden"] == model.HIDDEN
    assert man["layers"] == model.LAYERS
    expected = {"embed", "lm_head"} | {
        f"{kind}_tp{tp}"
        for kind in ("qkv", "kvupd", "attnout", "mlp")
        for tp in (1, 2, 4)
    }
    assert set(man["modules"]) == expected
    for name, meta in man["weights"].items():
        path = os.path.join(outdir, meta["file"])
        assert os.path.exists(path), name
        n = np.prod(meta["shape"])
        assert os.path.getsize(path) == 4 * n, f"{name}: size mismatch"


def test_weight_binaries_roundtrip(outdir):
    man = json.load(open(os.path.join(outdir, "manifest.json")))
    w = model.make_weights(seed=0)
    meta = man["weights"]["l0.up"]
    data = np.fromfile(os.path.join(outdir, meta["file"]), dtype="<f4").reshape(
        meta["shape"]
    )
    np.testing.assert_array_equal(data, w["l0.up"])


def test_oracle_reproducible(outdir):
    oracle = json.load(open(os.path.join(outdir, "oracle.json")))
    w = model.make_weights(seed=0)
    tokens = list(oracle["prompt"])
    for expect in oracle["generated"]:
        logits = model.reference_decode(w, tokens)
        nxt = int(np.argmax(logits[-1]))
        assert nxt == expect
        tokens.append(nxt)


def test_hlo_parameter_counts(outdir):
    """attn modules take 6 parameters, mlp 4 — what runtime/executor.rs
    feeds must match."""
    for tp in model.TP_CHOICES:
        for kind in ("qkv", "kvupd", "attnout", "mlp"):
            text = open(os.path.join(outdir, f"{kind}_tp{tp}.hlo.txt")).read()
            entry = [l for l in text.splitlines() if l.startswith("ENTRY")][0]
            assert entry.count("parameter") >= 1 or "Arg_" in text
