"""L1 correctness: decode attention over paged KV layouts vs the oracle.

The same kernel body must produce identical results under all three
Table-2 layouts, because `kv_stride_order()` + permute recovers the
kernel view (§4.1.1) — that is the property that lets Gyges change the
storage layout without touching the attention kernel.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention_pallas, ref

LAYOUTS = list(ref.LAYOUTS.keys())


def make_case(seed, blocks, tpb, heads, hd):
    rng = np.random.default_rng(seed)
    kv_view = jnp.asarray(
        rng.standard_normal((blocks, 2, tpb, heads, hd)), jnp.float32
    )
    q = jnp.asarray(rng.standard_normal((heads, hd)), jnp.float32)
    return q, kv_view


@pytest.mark.parametrize("layout", LAYOUTS)
def test_all_layouts_agree_with_oracle(layout):
    q, kv_view = make_case(0, blocks=4, tpb=16, heads=8, hd=32)
    ctx = 50
    want = ref.decode_attention(q, kv_view, ctx)
    stored = attention_pallas.store_kv(kv_view, layout)
    got = attention_pallas.decode_attention(q, stored, ctx, layout=layout)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("ctx", [1, 15, 16, 17, 63, 64])
def test_context_boundaries(ctx):
    """Edge contexts around block boundaries must mask correctly."""
    q, kv_view = make_case(1, blocks=4, tpb=16, heads=4, hd=16)
    want = ref.decode_attention(q, kv_view, ctx)
    stored = attention_pallas.store_kv(kv_view, "header_centric")
    got = attention_pallas.decode_attention(q, stored, ctx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    blocks=st.integers(1, 5),
    tpb=st.sampled_from([8, 16]),
    heads=st.sampled_from([1, 2, 4, 8]),
    hd=st.sampled_from([16, 32]),
    layout=st.sampled_from(LAYOUTS),
)
def test_hypothesis_sweep(seed, blocks, tpb, heads, hd, layout):
    q, kv_view = make_case(seed, blocks, tpb, heads, hd)
    rng = np.random.default_rng(seed + 1)
    ctx = int(rng.integers(1, blocks * tpb + 1))
    want = ref.decode_attention(q, kv_view, ctx)
    stored = attention_pallas.store_kv(kv_view, layout)
    got = attention_pallas.decode_attention(q, stored, ctx, layout=layout)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_stride_orders_match_rust():
    """Must equal rust kvcache::layout::kv_stride_order exactly."""
    assert ref.kv_stride_order("page_friendly") == (0, 1, 2, 3)
    assert ref.kv_stride_order("header_centric") == (0, 2, 3, 1)
    assert ref.kv_stride_order("raw") == (1, 0, 2, 3)


@pytest.mark.parametrize("layout", LAYOUTS)
def test_layout_roundtrip(layout):
    _, kv_view = make_case(5, blocks=2, tpb=8, heads=4, hd=16)
    stored = ref.to_layout(kv_view, layout)
    back = ref.from_layout(stored, layout)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(kv_view))


def test_header_centric_head_span_contiguous():
    """Mirror of the Rust layout test: in header-centric storage one
    (block, head) pair's K+V occupy one contiguous span."""
    blocks, tpb, heads, hd = 2, 8, 4, 16
    # element ids in kernel-view order
    n = blocks * 2 * tpb * heads
    ids = jnp.arange(n * hd).reshape(blocks, 2, tpb, heads, hd)
    stored = ref.to_layout(ids, "header_centric")
    flat = np.asarray(stored).reshape(-1)
    # for block 0, head 2: collect positions of its elements
    positions = [
        i for i, v in enumerate(flat)
        if (v // hd) % heads == 2 and v < 2 * tpb * heads * hd
    ]
    span = max(positions) - min(positions) + 1
    assert span == len(positions), "head span must be contiguous"


def test_softmax_normalization():
    """Output must be a convex combination of V rows (weights sum to 1)."""
    heads, hd = 2, 8
    kv_view = jnp.ones((1, 2, 4, heads, hd), jnp.float32)
    q = jnp.zeros((heads, hd), jnp.float32)
    stored = attention_pallas.store_kv(kv_view)
    out = attention_pallas.decode_attention(q, stored, 4)
    np.testing.assert_allclose(np.asarray(out), np.ones((heads, hd)), rtol=1e-6)
