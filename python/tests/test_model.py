"""L2 correctness: the TP-sharded module decomposition equals the full
reference model for every TP degree — the property the Rust runtime's
per-layer reduction relies on."""

import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def weights():
    return model.make_weights(seed=0)


@pytest.fixture(scope="module")
def ref_logits(weights):
    return model.reference_decode(weights, TOKENS)


TOKENS = [1, 5, 42, 7, 300, 999, 0, 511]


@pytest.mark.parametrize("tp", model.TP_CHOICES)
def test_sharded_equals_reference(weights, ref_logits, tp):
    got = model.sharded_decode(weights, TOKENS, tp)
    np.testing.assert_allclose(got, ref_logits, rtol=1e-3, atol=1e-3)
    assert (got.argmax(-1) == ref_logits.argmax(-1)).all(), "greedy tokens must match"


def test_greedy_generation_deterministic(weights):
    prompt = [1, 5, 42]
    seqs = []
    for _ in range(2):
        toks = list(prompt)
        for _ in range(4):
            logits = model.reference_decode(weights, toks)
            toks.append(int(np.argmax(logits[-1])))
        seqs.append(toks)
    assert seqs[0] == seqs[1]


def test_padded_shard_shapes(weights):
    for tp in model.TP_CHOICES:
        ps = model.padded_shard_inner(tp)
        assert ps % model.BLOCK_INNER == 0
        assert ps >= model.INNER // tp
        up_p, down_p = model.shard_mlp_weights(weights, 0, tp, 0)
        assert up_p.shape == (model.HIDDEN, ps)
        assert down_p.shape == (ps, model.HIDDEN)
        # pad region must be exactly zero
        shard = model.INNER // tp
        assert np.all(up_p[:, shard:] == 0.0)
        assert np.all(down_p[shard:, :] == 0.0)


def test_padding_overhead_is_bounded():
    """inner=960: tp4 shards 240→256 = 6.7% pad; within the paper's ≤14%."""
    for tp in model.TP_CHOICES:
        shard = model.INNER // tp
        overhead = (model.padded_shard_inner(tp) - shard) / shard
        assert 0.0 <= overhead <= 0.14, f"tp{tp}: {overhead}"


def test_attn_shards_partition_heads(weights):
    full_wqkv = weights["l0.wqkv"].reshape(model.HIDDEN, 3, model.HEADS, model.HEAD_DIM)
    for tp in model.TP_CHOICES:
        h_shard = model.HEADS // tp
        got = np.concatenate(
            [
                model.shard_attn_weights(weights, 0, tp, r)[0].reshape(
                    model.HIDDEN, 3, h_shard, model.HEAD_DIM
                )
                for r in range(tp)
            ],
            axis=2,
        )
        np.testing.assert_array_equal(got, full_wqkv)


def test_weights_deterministic_by_seed():
    a = model.make_weights(seed=0)
    b = model.make_weights(seed=0)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    c = model.make_weights(seed=1)
    assert np.abs(a["emb"] - c["emb"]).max() > 0
