"""L1 correctness: the padded-FFN Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, padding splits and seeds; every case asserts
allclose against ref.ffn (the UNpadded computation — so these tests check
both the kernel and the §4.2 padding identity at once).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ffn_pallas, ref

BLOCK_INNER = 128


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


def run_case(seed, m_blocks, hidden, shards, shard_cols, pad_cols):
    rng = np.random.default_rng(seed)
    m = 8 * m_blocks
    inner = shards * shard_cols
    x = rand(rng, m, hidden)
    up = rand(rng, hidden, inner)
    down = rand(rng, inner, hidden)
    up_p, down_p = ref.pad_ffn_weights(up, down, shards, pad_cols)
    padded_inner = up_p.shape[1]
    if padded_inner % BLOCK_INNER != 0:
        pytest.skip("padded inner must align to the block for this kernel")
    want = ref.ffn(x, up, down)
    got = ffn_pallas.ffn_padded(x, up_p, down_p, block_m=8, block_inner=BLOCK_INNER)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shards,shard_cols,pad", [
    (4, 96, 32),   # 4×(96+32) = 512: the paper's per-boundary padding
    (2, 192, 64),  # 2×(192+64) = 512
    (4, 128, 0),   # already aligned: zero padding
    (1, 384, 128), # single shard, large pad
])
def test_padded_ffn_matches_unpadded(shards, shard_cols, pad):
    run_case(0, m_blocks=2, hidden=64, shards=shards,
             shard_cols=shard_cols, pad_cols=[pad] * shards)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    m_blocks=st.integers(1, 3),
    hidden=st.sampled_from([32, 64, 128]),
    shards=st.sampled_from([1, 2, 4]),
)
def test_padded_ffn_hypothesis_sweep(seed, m_blocks, hidden, shards):
    # shard_cols chosen so each padded shard is exactly one 128-block
    shard_cols = 128 - 16  # 112 real + 16 pad per shard
    run_case(seed, m_blocks, hidden, shards, shard_cols, [16] * shards)


def test_uneven_padding_per_boundary():
    # Different pad widths per shard, still block-aligned in total.
    run_case(3, m_blocks=1, hidden=64, shards=4,
             shard_cols=120, pad_cols=[8, 8, 8, 8])


def test_gyges_tiny_shapes():
    """The exact shapes the serving artifacts use (inner 960 → 1024@tp4)."""
    from compile import model
    rng = np.random.default_rng(7)
    x = rand(rng, 8, model.HIDDEN)
    w = model.make_weights(seed=1)
    up, down = w["l0.up"], w["l0.down"]
    want = ref.ffn(jnp.asarray(x), jnp.asarray(up), jnp.asarray(down))
    for tp in model.TP_CHOICES:
        total = jnp.zeros_like(want)
        for r in range(tp):
            up_p, down_p = model.shard_mlp_weights(w, 0, tp, r)
            part = ffn_pallas.ffn_padded(
                x, jnp.asarray(up_p), jnp.asarray(down_p),
                block_m=8, block_inner=model.BLOCK_INNER,
            )
            total = total + part
        np.testing.assert_allclose(
            np.asarray(total), np.asarray(want), rtol=3e-4, atol=3e-4,
            err_msg=f"tp={tp}",
        )


def test_zero_input_gives_zero_output():
    x = jnp.zeros((8, 64), jnp.float32)
    rng = np.random.default_rng(9)
    up = rand(rng, 64, 128)
    down = rand(rng, 128, 64)
    got = ffn_pallas.ffn_padded(x, up, down)
    # gelu(0) = 0 → output must be exactly 0
    assert float(jnp.abs(got).max()) == 0.0


def test_vmem_and_mxu_estimates():
    vm = ffn_pallas.vmem_footprint_bytes(h=256, inner=1024)
    assert 0 < vm < 16 * 1024 * 1024, "must fit VMEM"
    assert ffn_pallas.mxu_utilization_estimate(256) == 1.0
    assert ffn_pallas.mxu_utilization_estimate(256, block_m=4) < 1.0
