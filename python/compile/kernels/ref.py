"""Pure-jnp correctness oracles for the Pallas kernels.

Everything here is straight-line jax.numpy — no pallas, no tricks — so a
disagreement between a kernel and this file is a kernel bug. The FFN-
padding construction mirrors rust/src/weights/ffn.rs (the Rust twin is
property-tested against the same identity, Eq. 2 of the paper), and
`kv_stride_order` mirrors rust/src/kvcache/layout.rs.
"""

import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------
# FFN (paper §4.2, Eq. 1–2)
# ---------------------------------------------------------------------

def gelu(x):
    """tanh-approximated GELU (must match the Pallas kernel exactly)."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def ffn(x, up, down):
    """FFN(I) = f(I · U) · D."""
    return gelu(x @ up) @ down


def pad_ffn_weights(up, down, shards, pad_cols):
    """Build (U', D') per §4.2: U gains zero columns after each column
    shard; D gains matching zero rows. pad_cols is per-shard.

    Returns (up_padded, down_padded).
    """
    h, i = up.shape
    i2, h2 = down.shape
    assert i == i2 and h == h2 and i % shards == 0
    assert len(pad_cols) == shards
    shard_w = i // shards
    up_parts, down_parts = [], []
    for s in range(shards):
        up_parts.append(up[:, s * shard_w:(s + 1) * shard_w])
        down_parts.append(down[s * shard_w:(s + 1) * shard_w, :])
        if pad_cols[s] > 0:
            up_parts.append(jnp.zeros((h, pad_cols[s]), up.dtype))
            down_parts.append(jnp.zeros((pad_cols[s], h), down.dtype))
    return jnp.concatenate(up_parts, axis=1), jnp.concatenate(down_parts, axis=0)


def ffn_padded_ref(x, up, down, shards, pad_cols):
    """FFN'(I) = f(I · U') · D' — must equal ffn(x, up, down)."""
    up_p, down_p = pad_ffn_weights(up, down, shards, pad_cols)
    return gelu(x @ up_p) @ down_p


# ---------------------------------------------------------------------
# KV layouts (paper §4.1, Table 2) — must mirror rust kvcache::layout
# ---------------------------------------------------------------------

# Kernel-view dimension order is [Block, Kv, Token, Header].
LAYOUTS = {
    "raw": ("kv", "block", "token", "header"),
    "page_friendly": ("block", "kv", "token", "header"),
    "header_centric": ("block", "header", "kv", "token"),
}


def kv_stride_order(layout):
    """For each kernel-view dim [Block, Kv, Token, Header], which storage
    axis supplies it. `stored.transpose(kv_stride_order(l))` yields the
    kernel view. Mirrors rust `kvcache::layout::kv_stride_order`.
    """
    view = ("block", "kv", "token", "header")
    storage = LAYOUTS[layout]
    return tuple(storage.index(d) for d in view)


def to_layout(kv_view, layout):
    """Store a kernel-view array [Block, Kv, Token, Header, Dim] under
    `layout` (the trailing head-dim axis always stays innermost)."""
    view = ("block", "kv", "token", "header")
    storage = LAYOUTS[layout]
    perm = tuple(view.index(d) for d in storage) + (4,)
    return jnp.transpose(kv_view, perm)


def from_layout(kv_stored, layout):
    """Recover the kernel view from storage via kv_stride_order (§4.1.1's
    permute(*stride_order))."""
    return jnp.transpose(kv_stored, kv_stride_order(layout) + (4,))


# ---------------------------------------------------------------------
# Decode attention over paged KV (oracle for the Pallas kernel)
# ---------------------------------------------------------------------

def decode_attention(q, kv_view, context_len):
    """Single-token decode attention.

    q:       [heads, head_dim]
    kv_view: [blocks, 2, tokens_per_block, heads, head_dim] — note the
             kernel view carries K/V at axis 1 and heads at axis 3.
    context_len: number of valid tokens.

    Returns [heads, head_dim].
    """
    blocks, two, tpb, heads, hd = kv_view.shape
    assert two == 2
    k = kv_view[:, 0].reshape(blocks * tpb, heads, hd)
    v = kv_view[:, 1].reshape(blocks * tpb, heads, hd)
    scale = 1.0 / np.sqrt(hd)
    scores = jnp.einsum("hd,thd->ht", q, k) * scale  # [heads, tokens]
    mask = jnp.arange(blocks * tpb)[None, :] < context_len
    scores = jnp.where(mask, scores, -1e30)
    probs = jnp.exp(scores - scores.max(axis=1, keepdims=True))
    probs = probs / probs.sum(axis=1, keepdims=True)
    return jnp.einsum("ht,thd->hd", probs, v)
