"""Layer-1 Pallas kernel: the padding-compatible FFN of paper §4.2.

FFN'(I) = gelu(I · U') · D'  with U' column-padded and D' row-padded at
TP-shard boundaries so every shard is page-aligned on the serving side.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the 2 MiB CUDA-page
constraint maps to MXU/VMEM tiling — the kernel's inner-dimension grid is
blocked so that each padded shard is a whole number of blocks, making a
TP re-shard pure block-dropping in the BlockSpec index map. Pad blocks of
U' are zero, and gelu(0)·0-rows of D' contribute nothing, so skipping or
keeping them is numerically identical; we keep them (interpret=True runs
on CPU where the skip is a no-op anyway) and document the VMEM/MXU
accounting in EXPERIMENTS.md §Perf.

The kernel MUST be lowered with interpret=True for the CPU PJRT runtime
(real-TPU lowering emits a Mosaic custom-call the CPU plugin cannot run).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _ffn_kernel(x_ref, up_ref, down_ref, o_ref):
    """One (m-block, inner-block) grid step.

    Grid: (M/bm, I'/bi). Each step computes the partial product
    gelu(x·U'[:, j]) · D'[j, :] and accumulates into the output block
    (whose index map revisits the same block for every j).
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    h = ref.gelu(jnp.dot(x, up_ref[...], preferred_element_type=jnp.float32))
    o_ref[...] += jnp.dot(h, down_ref[...], preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_inner"))
def ffn_padded(x, up_p, down_p, block_m=8, block_inner=128):
    """Padding-compatible FFN via a Pallas kernel.

    x:      [M, H]       activations
    up_p:   [H, I']      column-padded up-projection (I' = padded inner)
    down_p: [I', H]      row-padded down-projection

    Block sizes default to MXU-friendly multiples of (8, 128); M and I'
    must divide by them (the model pads its shapes accordingly).
    """
    m, h = x.shape
    h2, inner = up_p.shape
    inner2, h3 = down_p.shape
    assert h == h2 and h == h3 and inner == inner2, "shape mismatch"
    assert m % block_m == 0, f"M={m} must divide block_m={block_m}"
    assert inner % block_inner == 0, f"I'={inner} must divide block_inner={block_inner}"
    n_inner = inner // block_inner

    return pl.pallas_call(
        _ffn_kernel,
        grid=(m // block_m, n_inner),
        in_specs=[
            pl.BlockSpec((block_m, h), lambda i, j: (i, 0)),
            pl.BlockSpec((h, block_inner), lambda i, j: (0, j)),
            pl.BlockSpec((block_inner, h), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, h), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, h), x.dtype),
        interpret=True,
    )(x, up_p, down_p)


def vmem_footprint_bytes(h, inner, block_m=8, block_inner=128, dtype_bytes=4):
    """Static VMEM usage estimate per grid step (DESIGN.md §Perf): the
    x block, one U' column block, one D' row block, and the accumulator."""
    x_blk = block_m * h
    up_blk = h * block_inner
    down_blk = block_inner * h
    acc = block_m * h
    return (x_blk + up_blk + down_blk + acc) * dtype_bytes


def mxu_utilization_estimate(h, block_m=8, block_inner=128):
    """Fraction of MXU lanes active per inner step: the (8,128) systolic
    tile is fully occupied iff block sizes are multiples of the tile."""
    tile_m, tile_n = 8, 128
    eff_m = min(block_m, tile_m) / tile_m
    eff_n = min(block_inner, tile_n) / tile_n
    eff_k = 1.0 if h % tile_n == 0 else (h % tile_n) / tile_n
    return eff_m * eff_n * eff_k
