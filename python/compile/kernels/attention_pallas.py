"""Layer-1 Pallas kernel: decode attention over the page-friendly
header-centric KV layout (paper §4.1, Table 2).

The KV cache is stored `[Block, Header, K/V, Token]` — each head's K+V
within a block is one contiguous span, which is what makes per-head
migration in-place on the serving side. The kernel view expected by
attention is `[Block, K/V, Token, Header]`; `kv_stride_order()` supplies
the permutation (§4.1.1) so the kernel body is layout-agnostic.

TPU adaptation: the grid iterates (head, block); each step streams one
head-contiguous KV tile HBM→VMEM — exactly the contiguity the
header-centric layout guarantees — and accumulates an online softmax
(flash-decoding style: running max / running sum carried in the output
accumulators between grid steps).

interpret=True is mandatory for the CPU PJRT runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _decode_attn_kernel(ctx_ref, q_ref, kv_ref, o_ref, m_ref, l_ref, *, tokens_per_block):
    """Grid (heads, blocks): online-softmax accumulation per head.

    ctx_ref: [1]                      scalar context length (SMEM-style)
    q_ref:  [1, head_dim]             this head's query
    kv_ref: [1, 2, tpb, 1, head_dim]  this (block, head)'s K and V span
    o_ref:  [1, head_dim]             output accumulator (revisited)
    m_ref:  [1, 1]                    running max
    l_ref:  [1, 1]                    running sum
    """
    b = pl.program_id(1)
    ctx = ctx_ref[0]

    @pl.when(b == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :]  # [hd]
    k = kv_ref[0, 0, :, 0, :]  # [tpb, hd]
    v = kv_ref[0, 1, :, 0, :]  # [tpb, hd]
    scale = 1.0 / jnp.sqrt(jnp.array(q.shape[-1], jnp.float32))
    scores = (k @ q) * scale  # [tpb]
    token_ids = b * tokens_per_block + jax.lax.iota(jnp.int32, tokens_per_block)
    scores = jnp.where(token_ids < ctx, scores, -1e30)

    m_prev = m_ref[0, 0]
    l_prev = l_ref[0, 0]
    m_cur = jnp.maximum(m_prev, scores.max())
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(scores - m_cur)  # [tpb]
    l_cur = l_prev * alpha + p.sum()
    o_ref[0, :] = o_ref[0, :] * alpha + p @ v
    m_ref[0, 0] = m_cur
    l_ref[0, 0] = l_cur


@functools.partial(jax.jit, static_argnames=("layout",))
def decode_attention(q, kv_stored, context_len, layout="header_centric"):
    """Single-token decode attention over a paged KV cache.

    q:         [heads, head_dim]
    kv_stored: KV cache stored under `layout` (see ref.LAYOUTS); the
               header-centric storage shape is
               [blocks, heads, 2, tokens_per_block, head_dim].
    context_len: scalar int32 — number of valid tokens.

    Returns [heads, head_dim]. Must match ref.decode_attention on the
    kernel view.
    """
    # §4.1.1: permute(*kv_stride_order()) recovers the kernel view
    # [Block, Kv, Token, Header] without touching the kernel itself.
    order = ref.kv_stride_order(layout)
    kv_view = jnp.transpose(kv_stored, order + (4,))
    blocks, two, tpb, heads, hd = kv_view.shape
    assert two == 2

    ctx = jnp.asarray(context_len, jnp.int32).reshape(1)
    out, _m, l = pl.pallas_call(
        functools.partial(_decode_attn_kernel, tokens_per_block=tpb),
        grid=(heads, blocks),
        in_specs=[
            pl.BlockSpec((1,), lambda h, b: (0,)),
            pl.BlockSpec((1, hd), lambda h, b: (h, 0)),
            pl.BlockSpec((1, 2, tpb, 1, hd), lambda h, b: (b, 0, 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, hd), lambda h, b: (h, 0)),
            pl.BlockSpec((1, 1), lambda h, b: (h, 0)),
            pl.BlockSpec((1, 1), lambda h, b: (h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((heads, hd), jnp.float32),
            jax.ShapeDtypeStruct((heads, 1), jnp.float32),
            jax.ShapeDtypeStruct((heads, 1), jnp.float32),
        ],
        interpret=True,
    )(ctx, q.astype(jnp.float32), kv_view.astype(jnp.float32))
    return (out / l).astype(q.dtype)


def store_kv(kv_view, layout="header_centric"):
    """Store a kernel-view KV array under `layout` (helper used by the
    model and the tests). kv_view: [blocks, 2, tpb, heads, head_dim]."""
    view = ("block", "kv", "token", "header")
    storage = ref.LAYOUTS[layout]
    perm = tuple(view.index(d) for d in storage) + (4,)
    return jnp.transpose(kv_view, perm)


def vmem_footprint_bytes(tokens_per_block, head_dim, dtype_bytes=4):
    """Per-grid-step VMEM estimate: one head's KV span + q + accumulators."""
    kv_tile = 2 * tokens_per_block * head_dim
    q = head_dim
    acc = head_dim + 2
    return (kv_tile + q + acc) * dtype_bytes
