"""AOT export: lower every serving module to HLO **text** and write the
weight binaries + manifest that the Rust runtime consumes.

HLO text (not serialized HloModuleProto) is the interchange format: the
xla crate's xla_extension 0.5.1 rejects jax≥0.5's 64-bit instruction ids;
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and DESIGN.md).

Artifacts (under --outdir, default ../artifacts):
    embed.hlo.txt                       token -> hidden
    lm_head.hlo.txt                     hidden -> logits
    attn_tp{1,2,4}.hlo.txt              per-worker attention shard
    mlp_tp{1,2,4}.hlo.txt               per-worker padded-FFN shard
    weights/*.bin                       raw little-endian f32 tensors
    manifest.json                       shapes + model dims
    oracle.json                         greedy tokens the Rust e2e checks

Usage: (cd python && python -m compile.aot [--outdir ../artifacts])
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, example_args):
    """Lower a jitted function to XLA HLO text via StableHLO."""
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def export_all(outdir):
    os.makedirs(outdir, exist_ok=True)
    wdir = os.path.join(outdir, "weights")
    os.makedirs(wdir, exist_ok=True)

    m = model
    manifest = {
        "model": "gyges-tiny",
        "hidden": m.HIDDEN,
        "inner": m.INNER,
        "heads": m.HEADS,
        "head_dim": m.HEAD_DIM,
        "layers": m.LAYERS,
        "vocab": m.VOCAB,
        "tokens_per_block": m.TOKENS_PER_BLOCK,
        "s_max": m.S_MAX,
        "blocks": m.BLOCKS,
        "block_inner": m.BLOCK_INNER,
        "tp_choices": list(m.TP_CHOICES),
        "padded_shard_inner": {str(tp): m.padded_shard_inner(tp) for tp in m.TP_CHOICES},
        "modules": {},
        "weights": {},
    }

    # ---------------- HLO modules ----------------
    written = {}

    def emit(name, fn, args):
        text = to_hlo_text(fn, args)
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        written[name] = len(text)
        manifest["modules"][name] = f"{name}.hlo.txt"

    emit(
        "embed",
        m.embed_fn,
        (spec((), jnp.int32), spec((m.VOCAB, m.HIDDEN))),
    )
    emit(
        "lm_head",
        m.lm_head_fn,
        (spec((1, m.HIDDEN)), spec((m.VOCAB, m.HIDDEN))),
    )
    for tp in m.TP_CHOICES:
        h_shard = m.HEADS // tp
        kv_shape = (m.BLOCKS, h_shard, 2, m.TOKENS_PER_BLOCK, m.HEAD_DIM)
        qkv_shape = (3, h_shard, m.HEAD_DIM)
        # Attention is exported as THREE single-output modules so the Rust
        # runtime can keep every intermediate as a device buffer (PJRT
        # tuple outputs cannot be decomposed without a host round-trip).
        emit(
            f"qkv_tp{tp}",
            m.qkv_fn,
            (
                spec((1, m.HIDDEN)),
                spec((m.HIDDEN, 3 * h_shard * m.HEAD_DIM)),
                spec((m.HIDDEN,)),
            ),
        )
        emit(
            f"kvupd_tp{tp}",
            m.kv_update_fn,
            (spec(kv_shape), spec(qkv_shape), spec((), jnp.int32)),
        )
        emit(
            f"attnout_tp{tp}",
            m.attn_out_fn,
            (
                spec(qkv_shape),
                spec(kv_shape),
                spec((), jnp.int32),
                spec((h_shard * m.HEAD_DIM, m.HIDDEN)),
            ),
        )
        ps = m.padded_shard_inner(tp)
        emit(
            f"mlp_tp{tp}",
            m.mlp_fn,
            (
                spec((1, m.HIDDEN)),
                spec((m.HIDDEN, ps)),
                spec((ps, m.HIDDEN)),
                spec((m.HIDDEN,)),
            ),
        )

    # ---------------- weights ----------------
    weights = m.make_weights(seed=0)
    for name, arr in weights.items():
        fname = name.replace(".", "_") + ".bin"
        arr.astype("<f4").tofile(os.path.join(wdir, fname))
        manifest["weights"][name] = {"file": f"weights/{fname}", "shape": list(arr.shape)}

    # ---------------- oracle ----------------
    prompt = [1, 5, 42, 7, 300, 9, 250, 77]
    n_gen = 8
    tokens = list(prompt)
    for _ in range(n_gen):
        logits = m.reference_decode(weights, tokens)
        tokens.append(int(np.argmax(logits[-1])))
    oracle = {
        "prompt": prompt,
        "generated": tokens[len(prompt):],
        "note": "greedy decode; rust serve_e2e must reproduce exactly",
    }
    with open(os.path.join(outdir, "oracle.json"), "w") as f:
        json.dump(oracle, f, indent=1)

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    total = sum(written.values())
    print(f"wrote {len(written)} HLO modules ({total} chars), "
          f"{len(manifest['weights'])} weight tensors, oracle + manifest -> {outdir}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file alias (ignored)")
    args = ap.parse_args()
    export_all(args.outdir)


if __name__ == "__main__":
    main()
