"""Layer-2: the `gyges-tiny` transformer in JAX, decomposed for tensor
parallelism the way the Rust coordinator executes it.

The model is compiled into PER-WORKER, PER-MODULE executables so that the
Rust runtime owns every cross-worker reduction (the role NCCL all-reduce
plays in the paper's §2 description of TP):

    embed   : token_id                      -> hidden            (replicated)
    attn_tp : hidden, pos, kv, weights      -> o_partial, kv'    (one shard)
    mlp_tp  : hidden2, padded mlp weights   -> mlp_partial       (one shard)
    lm_head : hidden, embedding             -> logits            (replicated)

Rust drives, per layer:  h2 = hidden + Σ_workers o_partial;
                         h3 = h2 + Σ_workers mlp_partial.
That is exactly TP with the coordinator as the reduction fabric.

The attention module calls the header-centric Pallas kernel and the MLP
module calls the padded-FFN Pallas kernel, so both Layer-1 kernels lower
into the serving artifacts. Shapes must stay in sync with
rust/src/config/model.rs::gyges_tiny and runtime/artifact.rs.
"""

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import attention_pallas, ffn_pallas, ref

# ----------------------------------------------------------------------
# Architecture (kept deliberately "unaligned": inner=960 exercises the
# §4.2 padding machinery — TP4 shards of 240 pad to 256).
# ----------------------------------------------------------------------
HIDDEN = 256
INNER = 960
HEADS = 8
HEAD_DIM = 32
LAYERS = 4
VOCAB = 1024
TOKENS_PER_BLOCK = 16
S_MAX = 128
BLOCKS = S_MAX // TOKENS_PER_BLOCK
BLOCK_INNER = 128  # MXU-tile-aligned pad granularity (≙ the 2 MiB page)
EPS = 1e-5
TP_CHOICES = (1, 2, 4)


def padded_shard_inner(tp):
    """Padded per-shard inner size: ceil(shard / BLOCK_INNER) blocks."""
    shard = INNER // tp
    return -(-shard // BLOCK_INNER) * BLOCK_INNER


def rmsnorm(x, g):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + EPS) * g


# ----------------------------------------------------------------------
# Weight generation (deterministic; written to artifacts/ by aot.py and
# sliced into TP shards by the Rust runtime).
# ----------------------------------------------------------------------

def make_weights(seed=0):
    """All model weights, unpadded, as numpy arrays."""
    rng = np.random.default_rng(seed)

    def w(*shape, scale=None):
        scale = scale or 1.0 / np.sqrt(shape[0])
        return (rng.standard_normal(shape) * scale).astype(np.float32)

    weights = {"emb": w(VOCAB, HIDDEN, scale=0.02)}
    for l in range(LAYERS):
        weights[f"l{l}.wqkv"] = w(HIDDEN, 3 * HEADS * HEAD_DIM)
        weights[f"l{l}.wo"] = w(HEADS * HEAD_DIM, HIDDEN)
        weights[f"l{l}.up"] = w(HIDDEN, INNER)
        weights[f"l{l}.down"] = w(INNER, HIDDEN)
        weights[f"l{l}.ln1"] = np.ones(HIDDEN, np.float32)
        weights[f"l{l}.ln2"] = np.ones(HIDDEN, np.float32)
    return weights


def shard_attn_weights(weights, layer, tp, rank):
    """The attention shard worker `rank` of `tp` holds (head-split)."""
    h_shard = HEADS // tp
    wqkv = weights[f"l{layer}.wqkv"].reshape(HIDDEN, 3, HEADS, HEAD_DIM)
    wqkv_s = wqkv[:, :, rank * h_shard:(rank + 1) * h_shard, :].reshape(
        HIDDEN, 3 * h_shard * HEAD_DIM
    )
    wo = weights[f"l{layer}.wo"].reshape(HEADS, HEAD_DIM, HIDDEN)
    wo_s = wo[rank * h_shard:(rank + 1) * h_shard].reshape(h_shard * HEAD_DIM, HIDDEN)
    return wqkv_s, wo_s


def shard_mlp_weights(weights, layer, tp, rank):
    """The PADDED MLP shard (§4.2: zero columns in U, zero rows in D to
    the BLOCK_INNER boundary)."""
    shard = INNER // tp
    pad = padded_shard_inner(tp) - shard
    up = weights[f"l{layer}.up"][:, rank * shard:(rank + 1) * shard]
    down = weights[f"l{layer}.down"][rank * shard:(rank + 1) * shard, :]
    up_p = np.concatenate([up, np.zeros((HIDDEN, pad), np.float32)], axis=1)
    down_p = np.concatenate([down, np.zeros((pad, HIDDEN), np.float32)], axis=0)
    return up_p, down_p


# ----------------------------------------------------------------------
# Per-module forward functions (one HLO artifact each)
# ----------------------------------------------------------------------

def embed_fn(token_id, emb):
    """token_id: [] int32 → hidden [1, HIDDEN]."""
    return (jnp.take(emb, token_id, axis=0)[None, :],)


def lm_head_fn(hidden, emb):
    """Tied LM head: hidden [1, HIDDEN] → logits [VOCAB]."""
    return (jnp.dot(hidden[0], emb.T),)


def qkv_fn(hidden, wqkv, ln1):
    """Norm + QKV projection for one shard: → qkv [3, h_shard, HEAD_DIM].

    Single-output so the Rust runtime can keep the result as a device
    buffer (PJRT tuple buffers cannot be decomposed device-side)."""
    h_shard = wqkv.shape[1] // (3 * HEAD_DIM)
    x = rmsnorm(hidden, ln1)  # [1, H]
    return (jnp.dot(x, wqkv).reshape(3, h_shard, HEAD_DIM),)


def kv_update_fn(kv, qkv, pos):
    """Write this step's K,V into the header-centric cache at `pos`.

    kv: [BLOCKS, h_shard, 2, TOKENS_PER_BLOCK, HEAD_DIM]. Single output =
    the updated cache (device-resident on the Rust side)."""
    h_shard = kv.shape[1]
    k, v = qkv[1], qkv[2]
    block = pos // TOKENS_PER_BLOCK
    off = pos % TOKENS_PER_BLOCK
    upd_k = k.reshape(1, h_shard, 1, 1, HEAD_DIM)
    upd_v = v.reshape(1, h_shard, 1, 1, HEAD_DIM)
    # Storage axes: [Block, Header, K/V, Token, Dim]; axis 2 selects K(0)/V(1).
    kv = jax.lax.dynamic_update_slice(kv, upd_k, (block, 0, 0, off, 0))
    kv = jax.lax.dynamic_update_slice(kv, upd_v, (block, 0, 1, off, 0))
    return (kv,)


def attn_out_fn(qkv, kv, pos, wo):
    """Paged decode attention (Pallas kernel) + output projection:
    → o_partial [1, HIDDEN] (this rank's partial sum)."""
    h_shard = kv.shape[1]
    q = qkv[0]
    attn = attention_pallas.decode_attention(q, kv, pos + 1, layout="header_centric")
    return (jnp.dot(attn.reshape(1, h_shard * HEAD_DIM), wo),)


def attn_fn(hidden, pos, kv, wqkv, wo, ln1):
    """One worker's full attention shard (composition of the three
    single-output modules above — used by the Python-side reference and
    the tests; the Rust runtime executes the three modules separately).

    Returns (o_partial [1, HIDDEN], kv_updated).
    """
    (qkv,) = qkv_fn(hidden, wqkv, ln1)
    (kv,) = kv_update_fn(kv, qkv, pos)
    (o_partial,) = attn_out_fn(qkv, kv, pos, wo)
    return o_partial, kv


def mlp_fn(hidden2, up_p, down_p, ln2):
    """One worker's padded-FFN shard: hidden2 [1, HIDDEN] → [1, HIDDEN]."""
    x = rmsnorm(hidden2, ln2)
    # Pallas padded-FFN kernel (block_m must divide the batch: pad 1→8).
    x8 = jnp.concatenate([x, jnp.zeros((7, HIDDEN), x.dtype)], axis=0)
    out = ffn_pallas.ffn_padded(x8, up_p, down_p, block_m=8, block_inner=BLOCK_INNER)
    return (out[:1],)


# ----------------------------------------------------------------------
# Full-model reference (pure jnp, TP=1, no Pallas) — the oracle for the
# Rust e2e serving example and the pytest suite.
# ----------------------------------------------------------------------

def reference_decode(weights, tokens):
    """Greedy-decode verification path: feed `tokens` (list[int]) one at a
    time through the full model; return the logits after each position.
    """
    kv = [
        np.zeros((BLOCKS, HEADS, 2, TOKENS_PER_BLOCK, HEAD_DIM), np.float32)
        for _ in range(LAYERS)
    ]
    logits_all = []
    for pos, tok in enumerate(tokens):
        hidden = weights["emb"][tok][None, :].astype(np.float32)
        for l in range(LAYERS):
            x = np.asarray(
                rmsnorm(jnp.asarray(hidden), jnp.asarray(weights[f"l{l}.ln1"]))
            )
            qkv = (x @ weights[f"l{l}.wqkv"]).reshape(3, HEADS, HEAD_DIM)
            q, k, v = qkv[0], qkv[1], qkv[2]
            b, o = pos // TOKENS_PER_BLOCK, pos % TOKENS_PER_BLOCK
            kv[l][b, :, 0, o, :] = k
            kv[l][b, :, 1, o, :] = v
            kv_view = np.transpose(kv[l], ref.kv_stride_order("header_centric") + (4,))
            attn = np.asarray(
                ref.decode_attention(jnp.asarray(q), jnp.asarray(kv_view), pos + 1)
            )
            h2 = hidden + attn.reshape(1, HEADS * HEAD_DIM) @ weights[f"l{l}.wo"]
            x2 = np.asarray(
                rmsnorm(jnp.asarray(h2), jnp.asarray(weights[f"l{l}.ln2"]))
            )
            mlp = np.asarray(
                ref.ffn(
                    jnp.asarray(x2),
                    jnp.asarray(weights[f"l{l}.up"]),
                    jnp.asarray(weights[f"l{l}.down"]),
                )
            )
            hidden = h2 + mlp
        logits_all.append(hidden[0] @ weights["emb"].T)
    return np.stack(logits_all)


def sharded_decode(weights, tokens, tp):
    """TP-sharded decode mirroring EXACTLY what the Rust runtime does:
    per-layer partial sums across `tp` workers. Used to validate that the
    module decomposition is TP-exact before AOT export."""
    h_shard = HEADS // tp
    kv = [
        [
            jnp.zeros((BLOCKS, h_shard, 2, TOKENS_PER_BLOCK, HEAD_DIM), jnp.float32)
            for _ in range(tp)
        ]
        for _ in range(LAYERS)
    ]
    logits_all = []
    for pos, tok in enumerate(tokens):
        (hidden,) = embed_fn(jnp.int32(tok), jnp.asarray(weights["emb"]))
        for l in range(LAYERS):
            o_sum = jnp.zeros((1, HIDDEN), jnp.float32)
            for r in range(tp):
                wqkv_s, wo_s = shard_attn_weights(weights, l, tp, r)
                o_part, kv_new = attn_fn(
                    hidden,
                    jnp.int32(pos),
                    kv[l][r],
                    jnp.asarray(wqkv_s),
                    jnp.asarray(wo_s),
                    jnp.asarray(weights[f"l{l}.ln1"]),
                )
                kv[l][r] = kv_new
                o_sum = o_sum + o_part
            h2 = hidden + o_sum  # Rust-side reduction + residual
            mlp_sum = jnp.zeros((1, HIDDEN), jnp.float32)
            for r in range(tp):
                up_p, down_p = shard_mlp_weights(weights, l, tp, r)
                (m_part,) = mlp_fn(
                    h2,
                    jnp.asarray(up_p),
                    jnp.asarray(down_p),
                    jnp.asarray(weights[f"l{l}.ln2"]),
                )
                mlp_sum = mlp_sum + m_part
            hidden = h2 + mlp_sum
        (logits,) = lm_head_fn(hidden, jnp.asarray(weights["emb"]))
        logits_all.append(np.asarray(logits))
    return np.stack(logits_all)
