//! Bench: regenerate paper Figure 14 (end-to-end throughput/TTFT/TPOT:
//! Gyges vs Gyges⁻ vs KunServe vs LoongServe across load levels,
//! production-like trace).
//!
//! `--shard K/N [--out-dir DIR]` runs one stripe of the fig14 job list
//! and writes shard JSONL + manifest instead (merge the stripes with
//! `gyges sweep-merge fig14`).

use gyges::experiments as exp;
use gyges::util::Args;

fn main() {
    let args = Args::from_env();
    // Default horizon comes from the sweep registry (300 s for fig14)
    // so this bench, its --shard mode, and `gyges sweep-shard fig14`
    // all describe the same canonical run by default — the job-list
    // fingerprint rejects mixed horizons at merge time.
    let horizon = args.parsed_or("horizon", exp::named_sweep_default_horizon("fig14"));
    if args.get("shard").is_some() {
        std::process::exit(exp::shard::shard_cli_named(&args, "fig14"));
    }
    // QPS levels that sweep this trace from moderate to saturating load
    // (the paper highlights an SLO-critical level; for our trace mix that
    // knee sits near 10 qps).
    let rows = gyges::experiments::fig14(horizon, &[2.0, 6.0, 10.0]);
    assert_eq!(rows.len(), 12); // 3 loads × 4 systems
}
