//! Bench: regenerate paper Figure 14 (end-to-end throughput/TTFT/TPOT:
//! Gyges vs Gyges⁻ vs KunServe vs LoongServe across load levels,
//! production-like trace).

use gyges::util::Args;

fn main() {
    let args = Args::from_env();
    let horizon = args.parsed_or("horizon", 300.0);
    // QPS levels that sweep this trace from moderate to saturating load
    // (the paper highlights an SLO-critical level; for our trace mix that
    // knee sits near 10 qps).
    let rows = gyges::experiments::fig14(horizon, &[2.0, 6.0, 10.0]);
    assert_eq!(rows.len(), 12); // 3 loads × 4 systems
}
