//! Bench: regenerate paper Figure 13 (TPS trend around the t=120 s long
//! request; Gyges avoids the second scale-up that RR/LLF trigger).
//!
//! `--shard K/N [--out-dir DIR]` runs one stripe of the fig13 job list
//! and writes shard JSONL + manifest instead (merge the stripes with
//! `gyges sweep-merge fig13`).

use gyges::util::Args;

fn main() {
    let args = Args::from_env();
    if args.get("shard").is_some() {
        std::process::exit(gyges::experiments::shard::shard_cli_named(&args, "fig13"));
    }
    let rows = gyges::experiments::fig13();
    assert_eq!(rows.len(), 3);
    // Assert the figure's qualitative claim as a regression check.
    let get = |policy: &str| -> f64 {
        rows.iter()
            .find(|r| r.get("policy").and_then(|p| p.as_str()) == Some(policy))
            .and_then(|r| r.get("scale_ups"))
            .and_then(|v| v.as_f64())
            .unwrap()
    };
    let (gy, rr, llf) = (get("gyges"), get("rr"), get("llf"));
    println!("\nscale-ups: gyges={gy} rr={rr} llf={llf}");
    assert!(gy <= rr.max(llf), "gyges must not out-transform the baselines");
}
