//! Bench: regenerate paper Figure 13 (TPS trend around the t=120 s long
//! request; Gyges avoids the second scale-up that RR/LLF trigger).

fn main() {
    let rows = gyges::experiments::fig13();
    assert_eq!(rows.len(), 3);
    // Assert the figure's qualitative claim as a regression check.
    let get = |policy: &str| -> f64 {
        rows.iter()
            .find(|r| r.get("policy").and_then(|p| p.as_str()) == Some(policy))
            .and_then(|r| r.get("scale_ups"))
            .and_then(|v| v.as_f64())
            .unwrap()
    };
    let (gy, rr, llf) = (get("gyges"), get("rr"), get("llf"));
    println!("\nscale-ups: gyges={gy} rr={rr} llf={llf}");
    assert!(gy <= rr.max(llf), "gyges must not out-transform the baselines");
}
