//! Bench: regenerate paper Table 1 (TP1/TP2/TP4 max-seq + throughput) and
//! micro-time the engine model's step functions.

use gyges::config::{GpuSpec, ModelConfig};
use gyges::sim::EngineModel;
use gyges::util::stats::Bench;

fn main() {
    let rows = gyges::experiments::table1();
    assert_eq!(rows.len(), 3);

    let e = EngineModel::new(ModelConfig::qwen2_5_32b(), GpuSpec::h20());
    println!("\nmicro-benchmarks (hot paths behind every scheduling decision):");
    for tp in [1u64, 2, 4] {
        let r = Bench::new(&format!("decode_step(tp{tp}, b8, ctx1k)"))
            .iters(1000)
            .run(|| e.decode_step(tp, 8, 1000));
        println!("  {}", r.line());
    }
    let r = Bench::new("max_seq(tp4)").iters(1000).run(|| e.max_seq(4));
    println!("  {}", r.line());
    let r = Bench::new("prefill(tp4, 50k)").iters(1000).run(|| e.prefill(4, 50_000));
    println!("  {}", r.line());
}
