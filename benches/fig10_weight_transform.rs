//! Bench: regenerate paper Figure 10 (weight transformation + padding
//! overhead) and micro-time the per-layer migration model.

use gyges::config::ModelConfig;
use gyges::util::stats::Bench;
use gyges::weights::{run_weight_migration, WeightMigrationSpec, WeightStrategy};

fn main() {
    let rows = gyges::experiments::fig10();
    assert_eq!(rows.len(), 12);

    println!("\nmicro-benchmarks:");
    let spec = WeightMigrationSpec::paper_default(ModelConfig::qwen2_5_32b());
    for strat in [
        WeightStrategy::PartialSwap,
        WeightStrategy::GygesNoOverlap,
        WeightStrategy::Gyges,
    ] {
        let r = Bench::new(&format!("run_weight_migration({})", strat.name()))
            .iters(200)
            .run(|| run_weight_migration(&spec, strat).per_layer_time());
        println!("  {}", r.line());
    }
}
