//! §Perf harness: measured decode throughput of the REAL PJRT serving
//! path (gyges-tiny) per TP degree, plus the live-transformation cost.
//! This is the L3 hot path the perf pass optimizes; EXPERIMENTS.md §Perf
//! records the before/after of each iteration.

use gyges::runtime::TinyRuntime;
use std::time::Instant;

fn main() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let prompt = [1u32, 5, 42, 7];
    for tp in [1usize, 2, 4] {
        let mut rt = TinyRuntime::load(&dir, tp).unwrap();
        let mut sess = rt.new_session().unwrap();
        // warmup + prompt
        let _ = rt.generate(&mut sess, &prompt, 4).unwrap();
        let n = 48;
        let t0 = Instant::now();
        let _ = rt.generate(&mut sess, &[9], n).unwrap();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "decode tp{tp}: {:.1} tok/s  ({:.2} ms/step over {n} tokens)",
            (n + 1) as f64 / dt,
            dt * 1e3 / (n + 1) as f64
        );
    }
    // Transformation cost on the real model.
    let mut rt = TinyRuntime::load(&dir, 1).unwrap();
    let mut sess = rt.new_session().unwrap();
    let _ = rt.generate(&mut sess, &prompt, 8).unwrap();
    let t0 = Instant::now();
    rt.transform(&mut sess, 4).unwrap();
    let up = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    rt.transform(&mut sess, 1).unwrap();
    let down = t0.elapsed().as_secs_f64();
    println!(
        "live transform: up {:.1} ms ({} moved), down {:.1} ms",
        up * 1e3,
        gyges::util::fmt_bytes(rt.last_transform_bytes as u64),
        down * 1e3
    );
    // Session setup (weight shard materialization).
    let t0 = Instant::now();
    let _s = rt.new_session().unwrap();
    println!("new_session(tp1): {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);
}
