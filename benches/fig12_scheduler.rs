//! Bench: regenerate paper Figure 12 (RR vs LLF vs Gyges scheduling,
//! four models) and micro-time a routing decision.
//!
//! `--shard K/N [--out-dir DIR]` runs one stripe of the fig12 job list
//! and writes shard JSONL + manifest instead (merge the stripes with
//! `gyges sweep-merge fig12`).

use gyges::config::{ClusterConfig, ModelConfig, Policy};
use gyges::coordinator::{
    make_policy, ActiveRequest, ClusterView, HostIndex, Instance, LoadIndex,
};
use gyges::experiments as exp;
use gyges::sim::{EngineModel, SimTime};
use gyges::util::stats::Bench;
use gyges::util::Args;

fn main() {
    let args = Args::from_env();
    // Default horizon comes from the sweep registry so this bench, its
    // --shard mode, and `gyges sweep-shard fig12` all describe the same
    // canonical run by default.
    let horizon = args.parsed_or("horizon", exp::named_sweep_default_horizon("fig12"));
    if args.get("shard").is_some() {
        std::process::exit(exp::shard::shard_cli_named(&args, "fig12"));
    }
    let rows = gyges::experiments::fig12(horizon, &ModelConfig::eval_set());
    assert_eq!(rows.len(), 12); // 4 models × 3 policies

    println!("\nmicro-benchmarks (route() — the per-arrival hot path):");
    let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
    let engine = EngineModel::new(cfg.model.clone(), cfg.gpu.clone());
    let instances: Vec<Instance> = (0..64).map(|i| Instance::new(i, i / 8, vec![i], 1)).collect();
    // Route through the incremental merge-candidate + load indices, as
    // the simulator does (the fallback scan path is not the hot path).
    let index = HostIndex::build(&instances, 8);
    let load = LoadIndex::build(&instances, &engine);
    // The production path: the gyges composition of the filter/score
    // pipeline (the legacy GygesPolicy only exists behind the test-only
    // `legacy-policies` feature).
    let mut policy = make_policy(Policy::Gyges);
    let req = ActiveRequest::new(1, SimTime::ZERO, 1000, 100);
    let long = ActiveRequest::new(2, SimTime::ZERO, 50_000, 256);
    let view = ClusterView {
        instances: &instances,
        engine: &engine,
        cfg: &cfg,
        now: SimTime::ZERO,
        tp1: Some(&index),
        load: Some(&load),
        blocked_hosts: None,
        cache: None,
    };
    let r = Bench::new("gyges.route(short, 64 instances)")
        .iters(2000)
        .run(|| policy.route(&req, &view));
    println!("  {}", r.line());
    let r = Bench::new("gyges.route(long, 64 instances)")
        .iters(2000)
        .run(|| policy.route(&long, &view));
    println!("  {}", r.line());
}
