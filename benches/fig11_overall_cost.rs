//! Bench: regenerate paper Figure 11 (per-step overhead vs layers
//! transformed per step, all mechanisms incl. Seesaw).

use gyges::config::{GpuSpec, ModelConfig};
use gyges::transform::{estimate, Mechanism};
use gyges::util::stats::Bench;

fn main() {
    let rows = gyges::experiments::fig11();
    assert!(rows.len() >= 6);

    println!("\nmicro-benchmarks (cost estimation — used per routing decision):");
    let (m, g) = (ModelConfig::qwen2_5_32b(), GpuSpec::h20());
    for mech in [Mechanism::Gyges, Mechanism::Basic, Mechanism::Seesaw] {
        let r = Bench::new(&format!("estimate({mech:?})"))
            .iters(50)
            .run(|| estimate(&m, &g, 1, 4, 0.9, mech).visible);
        println!("  {}", r.line());
    }
}
