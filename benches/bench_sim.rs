//! Perf harness for the cluster-simulator hot paths. Emits a
//! machine-readable `BENCH_sim.json` (schema v3, documented in PERF.md)
//! so the events/sec and sweep wall-time trajectory is tracked from PR 1
//! onward.
//!
//!   cargo bench --bench bench_sim [-- --out BENCH_sim.json
//!       --requests 10000 --sweep-horizon 120 --samples 3
//!       --fleet-hosts 32 --route-requests 20000
//!       --queue calendar --curve-hosts 32,128,512,1250
//!       --curve-horizon 60 --curve-qps-per-instance 0.25]
//!
//! The 10k-instance hour-horizon point from the issue is
//! `--curve-hosts 1250 --curve-horizon 3600` (1250 hosts × 8 GPUs);
//! CI runs it from the scaling-curve-10k workflow_dispatch job.
//!
//! Measures:
//!  1. Single-threaded events/sec replaying a ~10k-request production
//!     trace through the full Gyges system (recorder + routing + steps),
//!     plus a profiled pass attributing wall time per event type and
//!     route/kick/drain_backlog sub-phase (schema v2).
//!  2. A large-fleet routing microbench (default 256 instances): the same
//!     short-heavy trace routed with the incremental LoadIndex/HostIndex
//!     versus the full-scan baseline, with the outcomes asserted
//!     decision-identical — the O(instances)→O(log) claim as a number.
//!  3. Wall time of the Figure-13-style policy × QPS sweep, serial vs
//!     parallel, with the merged outputs checked byte-identical.

use gyges::config::{ClusterConfig, ModelConfig, Policy};
use gyges::coordinator::{run_system, ClusterSim, SimOutcome, SystemKind};
use gyges::experiments::sweep::{
    results_to_jsonl, run_sweep_parallel, run_sweep_serial, sweep_threads, SweepJob,
};
use gyges::sim::{set_queue_backend, QueueBackend, SimTime};
use gyges::util::json::Json;
use gyges::util::Args;
use gyges::workload::{SloClass, Trace, TraceRequest};
use std::sync::Arc;
use std::time::Instant;

/// Policy × QPS grid around the Figure 13 operating point.
fn fig13_qps_sweep_jobs(horizon_s: f64) -> Vec<SweepJob> {
    let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
    let mut jobs = Vec::new();
    for qps in [2.0f64, 4.0, 6.0, 8.0] {
        let trace = Arc::new(Trace::production(0xF16_13, qps, horizon_s));
        for policy in [Policy::RoundRobin, Policy::LeastLoadFirst, Policy::Gyges] {
            jobs.push(SweepJob::new(
                format!("qps{qps}/{}", policy.name()),
                cfg.clone(),
                SystemKind::Gyges,
                Some(policy.into()),
                Arc::clone(&trace),
            ));
        }
    }
    jobs
}

/// Routing-dominated workload: a dense stream of short requests with tiny
/// outputs, so per-arrival routing (not decode stepping) is the bulk of
/// the event-loop work on a large fleet.
fn routing_trace(requests: usize) -> Trace {
    let mut t = Trace::default();
    for i in 0..requests {
        t.requests.push(TraceRequest {
            id: i as u64,
            arrival: SimTime::from_secs_f64(i as f64 * 0.005), // 200 qps
            input_len: 1000,
            output_len: 4,
            class: SloClass::Interactive,
            prefix: Vec::new(),
        });
    }
    t.sort();
    t
}

fn run_fleet(cfg: &ClusterConfig, trace: &Trace, indexed: bool) -> (SimOutcome, f64) {
    let mut sim = ClusterSim::new(cfg.clone(), SystemKind::Gyges, trace.clone());
    if !indexed {
        sim.disable_routing_index();
    }
    let t0 = Instant::now();
    let out = sim.run();
    let wall = t0.elapsed().as_secs_f64();
    assert!(out.error.is_none(), "routing microbench hit the event cap");
    (out, wall)
}

fn outcome_fingerprint(out: &SimOutcome) -> (String, gyges::coordinator::SimCounters) {
    (out.report.to_json().to_string(), out.counters)
}

/// Fleet-size scaling curve: one full simulator run per host count, all
/// shape knobs held fixed so points are comparable across bench runs.
/// Load scales with the fleet (`qps_per_instance × instances`) so every
/// point exercises routing + stepping at a proportional arrival rate.
fn scaling_curve(hosts_list: &[usize], horizon_s: f64, qps_per_instance: f64) -> Json {
    let mut points = Vec::new();
    for &hosts in hosts_list {
        let mut cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        cfg.hosts = hosts;
        let instances = cfg.total_gpus();
        let qps = qps_per_instance * instances as f64;
        let trace = Trace::production(0x5CA1E, qps, horizon_s);
        let requests = trace.len();
        println!("  {instances} instances ({hosts} hosts): {requests} requests at {qps:.0} qps");
        let mut sim = ClusterSim::new(cfg, SystemKind::Gyges, trace);
        let t0 = Instant::now();
        let out = sim.run();
        let wall = t0.elapsed().as_secs_f64();
        assert!(out.error.is_none(), "scaling-curve point {hosts} hosts hit the event cap");
        let eps = out.counters.events as f64 / wall;
        println!(
            "    {wall:.3} s wall, {} events → {eps:.0} events/s ({} completed)",
            out.counters.events, out.report.completed
        );
        let mut p = Json::obj();
        p.set("hosts", hosts)
            .set("instances", instances)
            .set("requests", requests)
            .set("events", out.counters.events)
            .set("wall_s", wall)
            .set("events_per_sec", eps);
        points.push(p);
    }
    let mut curve = Json::obj();
    curve
        .set("qps_per_instance", qps_per_instance)
        .set("horizon_s", horizon_s)
        .set("points", Json::Arr(points));
    curve
}

fn main() {
    let args = Args::from_env();
    let out_path = args.get_or("out", "BENCH_sim.json");
    let target_requests = args.parsed_or("requests", 10_000usize);
    let sweep_horizon = args.parsed_or("sweep-horizon", 120.0f64);
    let samples = args.parsed_or("samples", 3usize).max(1);
    let fleet_hosts = args.parsed_or("fleet-hosts", 32usize).max(1);
    let route_requests = args.parsed_or("route-requests", 20_000usize).max(100);
    let queue = args.get_or("queue", "calendar");
    let backend = QueueBackend::by_name(&queue).unwrap_or_else(|| {
        eprintln!("unknown --queue backend {queue:?} (expected calendar|heap)");
        std::process::exit(2);
    });
    set_queue_backend(backend);
    let curve_hosts: Vec<usize> = args
        .get_or("curve-hosts", "32,128")
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("bad --curve-hosts entry {s:?}")))
        .collect();
    let curve_horizon = args.parsed_or("curve-horizon", 60.0f64);
    let curve_qps = args.parsed_or("curve-qps-per-instance", 0.25f64);
    println!("event queue backend: {}", backend.name());

    // ---- 1. single-threaded events/sec on a ~10k-request trace --------
    // Production lengths at 10 qps: ~1000 s of simulated traffic ≈ 10k.
    let horizon = target_requests as f64 / 10.0;
    let trace = Trace::production(0xBE7C, 10.0, horizon);
    println!(
        "single-thread: replaying {} requests ({} tokens) through gyges/gyges",
        trace.len(),
        trace.total_tokens()
    );
    let mut best_wall = f64::INFINITY;
    let mut events = 0u64;
    let mut completed = 0usize;
    for s in 0..=samples {
        let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        let t0 = Instant::now();
        let out = run_system(cfg, SystemKind::Gyges, None, trace.clone());
        let wall = t0.elapsed().as_secs_f64();
        assert!(out.error.is_none(), "bench run hit the event cap");
        events = out.counters.events;
        completed = out.report.completed;
        if s > 0 {
            // sample 0 is warmup
            best_wall = best_wall.min(wall);
        }
        println!(
            "  sample {s}: {:.3} s wall, {} events, {:.0} events/s{}",
            wall,
            out.counters.events,
            out.counters.events as f64 / wall,
            if s == 0 { "  (warmup)" } else { "" }
        );
    }
    let events_per_sec = events as f64 / best_wall;
    println!(
        "single-thread best: {best_wall:.3} s wall, {events} events → {events_per_sec:.0} events/s ({completed} completed)"
    );

    // Profiled pass: per-event-type wall attribution (separate from the
    // timed samples so Instant overhead never pollutes events/sec).
    let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
    let mut sim = ClusterSim::new(cfg, SystemKind::Gyges, trace.clone());
    sim.enable_profiling();
    let profiled = sim.run();
    let prof = profiled.profile.expect("profiling was enabled");
    let c = profiled.counters;
    println!("per-event wall attribution (profiled pass):");
    println!("  arrival        {:>10.4} s over {} events", prof.arrival_s, c.arrival_events);
    println!("  step           {:>10.4} s over {} events", prof.step_s, c.step_events);
    println!(
        "  transform_done {:>10.4} s over {} events",
        prof.transform_done_s, c.transform_done_events
    );
    println!(
        "  backlog_wakeup {:>10.4} s over {} events",
        prof.backlog_wakeup_s, c.backlog_wakeup_events
    );
    println!(
        "  sub-phases: route {:.4} s / {} calls, kick {:.4} s / {} calls, drain {:.4} s",
        prof.route_s, c.routes, prof.kick_s, c.kicks, prof.drain_backlog_s
    );

    let mut per_event = Json::obj();
    let pair = |wall: f64, count: u64| {
        let mut o = Json::obj();
        o.set("events", count).set("wall_s", wall);
        o
    };
    per_event
        .set("arrival", pair(prof.arrival_s, c.arrival_events))
        .set("step", pair(prof.step_s, c.step_events))
        .set("transform_done", pair(prof.transform_done_s, c.transform_done_events))
        .set("backlog_wakeup", pair(prof.backlog_wakeup_s, c.backlog_wakeup_events))
        .set("stale", pair(0.0, c.stale_events));
    let mut sub = Json::obj();
    let mut route = Json::obj();
    route.set("calls", c.routes).set("wall_s", prof.route_s);
    let mut kick = Json::obj();
    kick.set("calls", c.kicks).set("wall_s", prof.kick_s);
    let mut drain = Json::obj();
    drain
        .set("wall_s", prof.drain_backlog_s)
        .set("retries", c.backlog_retries)
        .set("requeues", c.backlog_requeues)
        .set("suppressed", c.backlog_suppressed)
        .set("wait_s", c.backlog_wait.as_secs_f64());
    sub.set("route", route).set("kick", kick).set("drain_backlog", drain);

    // ---- 2. large-fleet routing microbench (indexed vs scan) ----------
    let mut fleet_cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
    fleet_cfg.hosts = fleet_hosts;
    let fleet_instances = fleet_cfg.total_gpus();
    let rtrace = routing_trace(route_requests);
    println!(
        "\nrouting microbench: {} instances ({} hosts), {} short requests",
        fleet_instances,
        fleet_hosts,
        rtrace.len()
    );
    let (scan_out, scan_wall) = run_fleet(&fleet_cfg, &rtrace, false);
    let (idx_out, idx_wall) = run_fleet(&fleet_cfg, &rtrace, true);
    assert_eq!(
        outcome_fingerprint(&scan_out),
        outcome_fingerprint(&idx_out),
        "indexed routing diverged from the scan baseline"
    );
    let scan_eps = scan_out.counters.events as f64 / scan_wall;
    let idx_eps = idx_out.counters.events as f64 / idx_wall;
    let route_speedup = idx_eps / scan_eps;
    println!(
        "  scan    {scan_wall:.3} s, {:.0} events/s\n  indexed {idx_wall:.3} s, {:.0} events/s → {route_speedup:.2}x (decisions identical)",
        scan_eps, idx_eps
    );
    let mut micro = Json::obj();
    let leg = |wall: f64, out: &SimOutcome| {
        let mut o = Json::obj();
        o.set("wall_s", wall)
            .set("events", out.counters.events)
            .set("events_per_sec", out.counters.events as f64 / wall);
        o
    };
    micro
        .set("instances", fleet_instances)
        .set("hosts", fleet_hosts)
        .set("requests", rtrace.len())
        .set("scan", leg(scan_wall, &scan_out))
        .set("indexed", leg(idx_wall, &idx_out))
        .set("speedup", route_speedup)
        .set("decisions_identical", true);

    // ---- 3. figure-13 policy × QPS sweep, serial vs parallel ----------
    let jobs = fig13_qps_sweep_jobs(sweep_horizon);
    let threads = sweep_threads();
    println!("\nsweep: {} jobs (policy × QPS), {} worker threads", jobs.len(), threads);
    let t0 = Instant::now();
    let serial = run_sweep_serial(&jobs);
    let serial_wall = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let parallel = run_sweep_parallel(&jobs, threads);
    let parallel_wall = t0.elapsed().as_secs_f64();
    let serial_bytes = results_to_jsonl(&serial);
    assert_eq!(
        serial_bytes,
        results_to_jsonl(&parallel),
        "parallel sweep diverged from serial (determinism violation)"
    );
    let speedup = serial_wall / parallel_wall;
    println!(
        "  serial {serial_wall:.3} s, parallel {parallel_wall:.3} s → {speedup:.2}x ({} jobs byte-identical)",
        jobs.len()
    );

    // ---- 4. fleet-size scaling curve ----------------------------------
    println!(
        "\nscaling curve: hosts {:?}, horizon {curve_horizon}s, {curve_qps} qps/instance",
        curve_hosts
    );
    let curve = scaling_curve(&curve_hosts, curve_horizon, curve_qps);

    // ---- 5. machine-readable report -----------------------------------
    let mut single = Json::obj();
    single
        .set("trace_requests", trace.len())
        .set("samples", samples)
        .set("trace_tokens", trace.total_tokens())
        .set("events", events)
        .set("wall_s", best_wall)
        .set("events_per_sec", events_per_sec)
        .set("completed", completed)
        .set("per_event", per_event)
        .set("sub_phases", sub);
    let mut sweep = Json::obj();
    sweep
        .set("jobs", jobs.len())
        .set("sweep_horizon_s", sweep_horizon)
        .set("threads", threads)
        .set("serial_wall_s", serial_wall)
        .set("parallel_wall_s", parallel_wall)
        .set("speedup", speedup)
        .set("byte_identical", true);
    let mut root = Json::obj();
    root.set("schema_version", 3u64)
        .set("bench", "bench_sim")
        .set("measured", true)
        .set("queue_backend", backend.name())
        .set("single_thread", single)
        .set("routing_microbench", micro)
        .set("sweep", sweep)
        .set("scaling_curve", curve);
    std::fs::write(&out_path, format!("{root}\n"))
        .unwrap_or_else(|e| panic!("writing {out_path}: {e}"));
    println!("\nwrote {out_path}");
}
