//! Perf harness for the cluster-simulator hot paths. Emits a
//! machine-readable `BENCH_sim.json` (schema documented in PERF.md) so the
//! events/sec and sweep wall-time trajectory is tracked from PR 1 onward.
//!
//!   cargo bench --bench bench_sim [-- --out BENCH_sim.json
//!       --requests 10000 --sweep-horizon 120 --samples 3]
//!
//! Measures:
//!  1. Single-threaded events/sec replaying a ~10k-request production
//!     trace through the full Gyges system (recorder + routing + steps).
//!  2. Wall time of the Figure-13-style policy × QPS sweep, serial vs
//!     parallel, with the merged outputs checked byte-identical.

use gyges::config::{ClusterConfig, ModelConfig, Policy};
use gyges::coordinator::{run_system, SystemKind};
use gyges::experiments::sweep::{
    results_to_jsonl, run_sweep_parallel, run_sweep_serial, sweep_threads, SweepJob,
};
use gyges::util::json::Json;
use gyges::util::Args;
use gyges::workload::Trace;
use std::sync::Arc;
use std::time::Instant;

/// Policy × QPS grid around the Figure 13 operating point.
fn fig13_qps_sweep_jobs(horizon_s: f64) -> Vec<SweepJob> {
    let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
    let mut jobs = Vec::new();
    for qps in [2.0f64, 4.0, 6.0, 8.0] {
        let trace = Arc::new(Trace::production(0xF16_13, qps, horizon_s));
        for policy in [Policy::RoundRobin, Policy::LeastLoadFirst, Policy::Gyges] {
            jobs.push(SweepJob::new(
                format!("qps{qps}/{}", policy.name()),
                cfg.clone(),
                SystemKind::Gyges,
                Some(policy),
                Arc::clone(&trace),
            ));
        }
    }
    jobs
}

fn main() {
    let args = Args::from_env();
    let out_path = args.get_or("out", "BENCH_sim.json");
    let target_requests = args.parsed_or("requests", 10_000usize);
    let sweep_horizon = args.parsed_or("sweep-horizon", 120.0f64);
    let samples = args.parsed_or("samples", 3usize).max(1);

    // ---- 1. single-threaded events/sec on a ~10k-request trace --------
    // Production lengths at 10 qps: ~1000 s of simulated traffic ≈ 10k.
    let horizon = target_requests as f64 / 10.0;
    let trace = Trace::production(0xBE7C, 10.0, horizon);
    println!(
        "single-thread: replaying {} requests ({} tokens) through gyges/gyges",
        trace.len(),
        trace.total_tokens()
    );
    let mut best_wall = f64::INFINITY;
    let mut events = 0u64;
    let mut completed = 0usize;
    for s in 0..=samples {
        let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
        let t0 = Instant::now();
        let out = run_system(cfg, SystemKind::Gyges, None, trace.clone());
        let wall = t0.elapsed().as_secs_f64();
        assert!(out.error.is_none(), "bench run hit the event cap");
        events = out.counters.events;
        completed = out.report.completed;
        if s > 0 {
            // sample 0 is warmup
            best_wall = best_wall.min(wall);
        }
        println!(
            "  sample {s}: {:.3} s wall, {} events, {:.0} events/s{}",
            wall,
            out.counters.events,
            out.counters.events as f64 / wall,
            if s == 0 { "  (warmup)" } else { "" }
        );
    }
    let events_per_sec = events as f64 / best_wall;
    println!(
        "single-thread best: {best_wall:.3} s wall, {events} events → {events_per_sec:.0} events/s ({completed} completed)"
    );

    // ---- 2. figure-13 policy × QPS sweep, serial vs parallel ----------
    let jobs = fig13_qps_sweep_jobs(sweep_horizon);
    let threads = sweep_threads();
    println!("\nsweep: {} jobs (policy × QPS), {} worker threads", jobs.len(), threads);
    let t0 = Instant::now();
    let serial = run_sweep_serial(&jobs);
    let serial_wall = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let parallel = run_sweep_parallel(&jobs, threads);
    let parallel_wall = t0.elapsed().as_secs_f64();
    let serial_bytes = results_to_jsonl(&serial);
    assert_eq!(
        serial_bytes,
        results_to_jsonl(&parallel),
        "parallel sweep diverged from serial (determinism violation)"
    );
    let speedup = serial_wall / parallel_wall;
    println!(
        "  serial {serial_wall:.3} s, parallel {parallel_wall:.3} s → {speedup:.2}x ({} jobs byte-identical)",
        jobs.len()
    );

    // ---- 3. machine-readable report -----------------------------------
    let mut single = Json::obj();
    single
        .set("trace_requests", trace.len())
        .set("trace_tokens", trace.total_tokens())
        .set("events", events)
        .set("wall_s", best_wall)
        .set("events_per_sec", events_per_sec)
        .set("completed", completed);
    let mut sweep = Json::obj();
    sweep
        .set("jobs", jobs.len())
        .set("sweep_horizon_s", sweep_horizon)
        .set("threads", threads)
        .set("serial_wall_s", serial_wall)
        .set("parallel_wall_s", parallel_wall)
        .set("speedup", speedup)
        .set("byte_identical", true);
    let mut root = Json::obj();
    root.set("schema_version", 1u64)
        .set("bench", "bench_sim")
        .set("measured", true)
        .set("single_thread", single)
        .set("sweep", sweep);
    std::fs::write(&out_path, format!("{}\n", root.to_string()))
        .unwrap_or_else(|e| panic!("writing {out_path}: {e}"));
    println!("\nwrote {out_path}");
}
