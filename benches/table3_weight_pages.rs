//! Bench: regenerate paper Table 3 (pages per tensor) and micro-time the
//! padding planner that runs at every model load.

use gyges::config::ModelConfig;
use gyges::util::stats::Bench;
use gyges::weights::LayerPadPlan;

fn main() {
    let rows = gyges::experiments::table3();
    assert_eq!(rows.len(), 4);

    println!("\nmicro-benchmarks:");
    for m in ModelConfig::eval_set() {
        let r = Bench::new(&format!("LayerPadPlan::plan({})", m.name))
            .iters(2000)
            .run(|| LayerPadPlan::plan(&m, 4).overhead_fraction());
        println!("  {}", r.line());
    }
}
