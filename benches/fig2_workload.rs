//! Bench: regenerate paper Figure 2 (workload dynamics) and micro-time
//! trace generation.

use gyges::util::stats::Bench;
use gyges::workload::Trace;

fn main() {
    let rows = gyges::experiments::fig2();
    assert!(!rows.is_empty());

    println!("\nmicro-benchmarks:");
    let r = Bench::new("Trace::hybrid_paper(1h)")
        .iters(5)
        .run(|| Trace::hybrid_paper(1, 3600.0).len());
    println!("  {}", r.line());
    let r = Bench::new("Trace::production(qps=2, 1h)")
        .iters(5)
        .run(|| Trace::production(1, 2.0, 3600.0).len());
    println!("  {}", r.line());
}
