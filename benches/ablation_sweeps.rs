//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!  A1. Phased-migration stage size: peak extra memory vs transformation
//!      time (the §4.1.2 knob behind the "<70 MB" claim).
//!  A2. SM allocation for the migration all-to-all (the §4.1 overlap
//!      trade-off: more SMs finish sooner but contend with decode).
//!  A3. Scheduler hysteresis (`long_hold_s`): oscillation vs. reserved
//!      high-TP capacity on the Figure-12 workload.
//!  A4. Layer stagger width: per-step overhead vs. transformation
//!      completion latency (§4.3).

use gyges::config::{GpuSpec, ModelConfig};
use gyges::experiments::{ablation_hold_jobs, named_sweep_default_horizon, ABLATION_HOLDS};
use gyges::kvcache::{run_kv_migration, KvMigrationSpec, KvMigrationStrategy};
use gyges::transform::{estimate, Mechanism};
use gyges::util::{fmt_bytes, Args, Table};

fn main() {
    let args = Args::from_env();
    if args.get("shard").is_some() {
        // A3 as a named sweep stripe (`--shard K/N`): JSONL + manifest
        // out, merged via `gyges sweep-merge ablation-hold`.
        std::process::exit(gyges::experiments::shard::shard_cli_named(&args, "ablation-hold"));
    }
    let model = ModelConfig::qwen2_5_32b();

    // ---------------- A1: stage size ----------------
    println!("A1 — phased migration stage size (4xTP1->TP4, 90% util):");
    let mut t = Table::new(["stage size", "peak extra/layer", "wall/layer", "stages"]);
    for mib in [8u64, 16, 32, 64, 128, 256] {
        let mut spec = KvMigrationSpec::paper_default(model.clone());
        spec.stage_bytes = mib * 1024 * 1024;
        let r = run_kv_migration(&spec, KvMigrationStrategy::Gyges);
        t.row([
            format!("{mib} MiB"),
            fmt_bytes(r.per_layer_peak_bytes),
            format!("{}", r.per_layer_wall),
            format!("{}", r.stages),
        ]);
    }
    t.print();
    println!("  -> paper's <70 MB peak requires stage <= 64 MiB; wall time is flat (pipelined).\n");

    // ---------------- A2: SM allocation ----------------
    println!("A2 — SMs granted to the migration all-to-all:");
    let mut t = Table::new(["SMs", "wall/layer", "vs 78 SMs"]);
    let full = {
        let spec = KvMigrationSpec::paper_default(model.clone());
        run_kv_migration(&spec, KvMigrationStrategy::GygesNoOverlap)
            .per_layer_wall
            .as_secs_f64()
    };
    for sms in [1u32, 4, 16, 39, 78] {
        let mut spec = KvMigrationSpec::paper_default(model.clone());
        spec.sms = sms;
        let r = run_kv_migration(&spec, KvMigrationStrategy::GygesNoOverlap);
        t.row([
            format!("{sms}"),
            format!("{}", r.per_layer_wall),
            format!("{:.2}x", r.per_layer_wall.as_secs_f64() / full),
        ]);
    }
    t.print();
    println!("  -> matches the paper's 522 ms @78SM vs 2240 ms @1SM anchors (4.3x).\n");

    // ---------------- A3: scheduler hysteresis ----------------
    let horizon = args.parsed_or("horizon", named_sweep_default_horizon("ablation-hold"));
    println!("A3 — gyges long-request hold (anti-oscillation), horizon {horizon}s:");
    let mut t = Table::new(["long_hold_s", "tput (tps)", "scale-ups", "scale-downs"]);
    // The hold values ride the sharded sweep driver (job keys hold0,
    // hold15, ... — the same list `--shard` stripes across processes).
    let results = gyges::experiments::sweep::run_sweep(&ablation_hold_jobs(horizon));
    gyges::experiments::sweep::warn_on_errors(&results);
    for (&hold, out) in ABLATION_HOLDS.iter().zip(&results) {
        t.row([
            format!("{hold}"),
            format!("{:.1}", out.report.throughput_tps),
            format!("{}", out.counters.scale_ups),
            format!("{}", out.counters.scale_downs),
        ]);
    }
    t.print();
    println!("  -> zero hold oscillates (one transformation per long); large holds waste TP1 capacity.\n");

    // ---------------- A4: overlap ablation across mechanisms ----------------
    println!("A4 — overlap ablation (full-model transformation, visible cost):");
    let mut t = Table::new(["mechanism", "wall", "visible", "hidden by overlap"]);
    let g = GpuSpec::h20();
    for (name, mech) in [
        ("gyges (overlap)", Mechanism::Gyges),
        ("gyges- (no overlap)", Mechanism::GygesNoOverlap),
    ] {
        let c = estimate(&model, &g, 1, 4, 0.9, mech);
        let hidden = 1.0 - c.visible.as_secs_f64() / c.total.as_secs_f64().max(1e-9);
        t.row([
            name.to_string(),
            format!("{}", c.total),
            format!("{}", c.visible),
            format!("{:.0}%", hidden.max(0.0) * 100.0),
        ]);
    }
    t.print();
}
