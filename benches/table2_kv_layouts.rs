//! Bench: regenerate paper Table 2 (layout → shift/trim complexity) and
//! micro-time the page-allocation hot path under each layout.

use gyges::config::ModelConfig;
use gyges::kvcache::{KvLayout, KvManager};
use gyges::util::stats::Bench;
use gyges::util::MIB;

fn main() {
    let rows = gyges::experiments::table2();
    assert_eq!(rows.len(), 3);

    println!("\nmicro-benchmarks (admit/append/finish on the page pool):");
    let model = ModelConfig::qwen2_5_32b();
    for layout in [KvLayout::Raw, KvLayout::PageFriendly, KvLayout::HeaderCentric] {
        let r = Bench::new(&format!("admit+grow+finish ({layout:?})"))
            .iters(50)
            .run(|| {
                let mut mgr = KvManager::new(&model, 1, layout, 256 * MIB);
                mgr.admit(1, 600).unwrap();
                for _ in 0..20 {
                    mgr.append(1, 512).unwrap();
                }
                mgr.finish(1).unwrap();
                mgr.shift_ops
            });
        println!("  {}", r.line());
    }
}
