//! Bench: regenerate paper Figure 9 (KV transformation time + memory) and
//! micro-time the migration planner.

use gyges::config::ModelConfig;
use gyges::kvcache::{run_kv_migration, KvMigrationSpec, KvMigrationStrategy};
use gyges::util::stats::Bench;

fn main() {
    let rows = gyges::experiments::fig9();
    assert_eq!(rows.len(), 12);

    println!("\nmicro-benchmarks (planner cost — runs on the scheduler's critical path):");
    let spec = KvMigrationSpec::paper_default(ModelConfig::qwen2_5_32b());
    for strat in [
        KvMigrationStrategy::Basic,
        KvMigrationStrategy::GygesNoOverlap,
        KvMigrationStrategy::Gyges,
    ] {
        let r = Bench::new(&format!("run_kv_migration({})", strat.name()))
            .iters(20)
            .run(|| run_kv_migration(&spec, strat).per_layer_visible);
        println!("  {}", r.line());
    }
}
