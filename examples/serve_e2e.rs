//! End-to-end validation (DESIGN.md §7): load the REAL gyges-tiny model
//! from the AOT artifacts, verify the Rust PJRT serving path reproduces
//! the Python oracle token-for-token, then serve a batched mixed workload
//! with LIVE parallelism transformations and report measured
//! latency/throughput.
//!
//! Requires `make artifacts` first.
//! Run: cargo run --release --example serve_e2e [-- --shorts 8 --longs 3]

use gyges::serve::{synthetic_workload, RealServer, ServerConfig};
use gyges::util::{fmt_bytes, Args, Table};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let artifacts = args.get_or("artifacts", "artifacts");
    let shorts = args.parsed_or("shorts", 8usize);
    let longs = args.parsed_or("longs", 3usize);

    println!("== gyges-tiny end-to-end over PJRT ({artifacts}/) ==");
    let mut server = RealServer::new(&artifacts, ServerConfig::default())?;
    println!(
        "model: hidden={} inner={} (padded/shard: tp1={} tp2={} tp4={}) layers={} heads={}",
        server.rt.man.hidden,
        server.rt.man.inner,
        server.rt.man.padded_shard_inner[&1],
        server.rt.man.padded_shard_inner[&2],
        server.rt.man.padded_shard_inner[&4],
        server.rt.man.layers,
        server.rt.man.heads,
    );

    // 1. Numerics gate: the serving path must match python bit-for-bit.
    server.rt.verify_oracle()?;
    println!("[1/3] oracle verified — rust PJRT serving == python reference\n");

    // 2. Mid-stream transformation correctness on the real model.
    {
        let mut sess = server.rt.new_session()?;
        let prompt = [2u32, 40, 7, 99];
        let mut logits = Vec::new();
        for &t in &prompt {
            logits = server.rt.step(&mut sess, t)?;
        }
        let before_tp = server.rt.tp;
        server.rt.transform(&mut sess, 4)?;
        println!(
            "[2/3] live TP{before_tp}->TP4 transformation mid-sequence moved {} of KV (header-centric per-head spans)",
            fmt_bytes(server.rt.last_transform_bytes as u64)
        );
        // continue decoding after the transformation
        let next = gyges::runtime::argmax(&logits) as u32;
        let _ = server.rt.step(&mut sess, next)?;
        server.rt.transform(&mut sess, 1)?;
    }

    // 3. Batched serving with transformation-aware placement.
    let reqs = synthetic_workload(args.parsed_or("seed", 42), shorts, longs, server.rt.man.vocab);
    let rep = server.serve(&reqs)?;
    println!("\n[3/3] served {} requests ({} short, {} long)", reqs.len(), shorts, longs);
    let mut t = Table::new(["metric", "value"]);
    t.row(["wall time", &format!("{:.2} s", rep.wall_s)]);
    t.row(["output tokens", &format!("{}", rep.total_tokens)]);
    t.row(["throughput", &format!("{:.1} tok/s", rep.throughput_tps)]);
    t.row(["TTFT p50 / p99", &format!("{:.1} / {:.1} ms", rep.ttft.p50 * 1e3, rep.ttft.p99 * 1e3)]);
    t.row(["TPOT p50 / p99", &format!("{:.1} / {:.1} ms", rep.tpot.p50 * 1e3, rep.tpot.p99 * 1e3)]);
    t.row(["transformations", &format!("{}", rep.transforms)]);
    t.row(["KV bytes re-sharded", &fmt_bytes(rep.transform_bytes as u64)]);
    t.print();
    Ok(())
}
