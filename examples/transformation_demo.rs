//! Transformation mechanics walkthrough: plans, costs and page math for a
//! single 4×(TP1) → TP4 transformation of Qwen2.5-32B — the paper's §4 in
//! one runnable tour.
//!
//! Run: cargo run --release --example transformation_demo

use gyges::config::{GpuSpec, ModelConfig};
use gyges::kvcache::{fig9_series, KvLayout};
use gyges::transform::{Mechanism, TransformPlan};
use gyges::util::{fmt_bytes, Table};
use gyges::weights::{fig10_series, page_counts, LayerPadPlan};

fn main() {
    let model = ModelConfig::qwen2_5_32b();
    let gpu = GpuSpec::h20();
    println!("== {} on {} ==\n", model.name, gpu.name);

    // --- §4.1: layouts ---
    println!("KV layouts (Table 2):");
    let mut t = Table::new(["layout", "hierarchy", "head span contiguous?"]);
    for l in [KvLayout::Raw, KvLayout::PageFriendly, KvLayout::HeaderCentric] {
        t.row([
            format!("{l:?}"),
            l.hierarchy().to_string(),
            if l == KvLayout::HeaderCentric {
                "yes — in-place migration".into()
            } else {
                "no".to_string()
            },
        ]);
    }
    t.print();

    // --- §4.1.2: KV migration strategies ---
    println!("\nKV migration (Figure 9, per layer):");
    let mut t = Table::new(["strategy", "visible time", "peak extra memory"]);
    for r in fig9_series(model.clone()) {
        t.row([
            r.strategy.name().to_string(),
            format!("{}", r.per_layer_visible),
            fmt_bytes(r.per_layer_peak_bytes),
        ]);
    }
    t.print();

    // --- §4.2: padding ---
    let plan = LayerPadPlan::plan(&model, 4);
    println!(
        "\nWeight padding (§4.2): TP4 shard {} -> {} pages/tensor, overhead {:.2}%",
        page_counts(&model, 4).per_tensor,
        plan.tensors[0].pages_per_shard(),
        plan.overhead_fraction() * 100.0
    );
    println!("Weight migration (Figure 10, per layer):");
    let mut t = Table::new(["strategy", "wall time", "bytes copied"]);
    for r in fig10_series(model.clone()) {
        t.row([
            r.strategy.name().to_string(),
            format!("{}", r.per_layer_time()),
            fmt_bytes(r.copied_bytes),
        ]);
    }
    t.print();

    // --- §4.3: the hybrid plan ---
    let plan = TransformPlan::build(&model, 1, 4, 2);
    println!(
        "\nHybrid plan (§4.3): {} ops over {} steps, reversed traversal (first op: layer {} {:?})",
        plan.ops.len(),
        plan.num_steps(),
        plan.ops[0].layer,
        plan.ops[0].kind
    );

    // --- the whole thing, costed ---
    println!("\nFull-model transformation cost (scale-up, 90% KV util):");
    let mut t = Table::new(["mechanism", "wall", "serving-visible", "blocking?"]);
    for mech in [Mechanism::Gyges, Mechanism::GygesNoOverlap, Mechanism::Basic, Mechanism::Seesaw] {
        let c = gyges::transform::estimate(&model, &gpu, 1, 4, 0.9, mech);
        t.row([
            format!("{mech:?}"),
            format!("{}", c.total),
            format!("{}", c.visible),
            if c.blocking { "yes".into() } else { "no".to_string() },
        ]);
    }
    t.print();
}
