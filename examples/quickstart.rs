//! Quickstart: the Gyges public API in ~60 lines.
//!
//! Builds the paper's default cluster (8×H20, Qwen2.5-32B, 8×TP1 at
//! start), serves a mixed short/long trace with the transformation-aware
//! scheduler, and prints throughput/TTFT/TPOT plus the transformation
//! activity.
//!
//! Run: cargo run --release --example quickstart

use gyges::config::{ClusterConfig, ModelConfig};
use gyges::coordinator::{run_system, SystemKind};
use gyges::workload::Trace;

fn main() {
    // 1. A cluster: model + GPU type + topology + scheduler knobs.
    let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
    println!(
        "cluster: {} on {} — {} GPUs, TP choices {:?}",
        cfg.model.name,
        cfg.gpu.name,
        cfg.total_gpus(),
        cfg.tp_choices
    );

    // 2. A workload: the §6.2.4 hybrid trace — 1K-token shorts at 60 qpm
    //    plus bursty 50K-token longs at ~1 qpm.
    let trace = Trace::hybrid_paper(/*seed=*/ 7, /*horizon_s=*/ 300.0);
    println!(
        "trace: {} requests ({} long beyond the TP1 limit)",
        trace.len(),
        trace.long_count(3_750)
    );

    // 3. Serve with full Gyges (header-centric KV + padded weights +
    //    overlap + Algorithm 1/2 scheduling).
    let out = run_system(cfg, SystemKind::Gyges, None, trace);

    // 4. Results.
    println!("{}", out.report.line());
    println!(
        "transformations: {} scale-ups, {} scale-downs (deferred {})",
        out.counters.scale_ups, out.counters.scale_downs, out.counters.deferred
    );

    // 5. The cost model behind every scheduling decision is public too:
    let cost = gyges::transform::estimate(
        &ModelConfig::qwen2_5_32b(),
        &gyges::config::GpuSpec::h20(),
        1,
        4,
        0.9,
        gyges::transform::Mechanism::Gyges,
    );
    println!(
        "one 4x(TP1)->TP4 transformation: wall {}, serving-visible {}",
        cost.total, cost.visible
    );
}
