//! Scheduler shoot-out (§6.2.4 / Figures 12–13): RR vs LLF vs the
//! transformation-aware scheduler on the hybrid workload, plus the
//! static-hybrid deployment of §3.3 as the no-transformation reference.
//!
//! Run: cargo run --release --example scheduler_compare [-- --horizon 300]

use gyges::baselines::{run_static_hybrid, StaticHybridConfig};
use gyges::config::{ClusterConfig, ModelConfig, Policy};
use gyges::coordinator::{run_system, SystemKind};
use gyges::util::{Args, Table};
use gyges::workload::Trace;

fn main() {
    let args = Args::from_env();
    let horizon = args.parsed_or("horizon", 300.0);
    let model_name = args.get_or("model", "qwen2.5-32b");
    let model = ModelConfig::by_name(&model_name).expect("unknown model");
    let cfg = ClusterConfig::paper_default(model);
    let trace = Trace::hybrid_paper(args.parsed_or("seed", 0xF16), horizon);
    println!(
        "hybrid workload on {}: {} requests over {horizon}s ({} long)\n",
        cfg.model.name,
        trace.len(),
        trace.long_count(3750)
    );

    let mut t = Table::new([
        "scheduler", "tput (tps)", "ttft p50", "tpot p50", "scale-ups", "scale-downs",
    ]);
    for policy in [Policy::RoundRobin, Policy::LeastLoadFirst, Policy::Gyges] {
        let out = run_system(cfg.clone(), SystemKind::Gyges, Some(policy.into()), trace.clone());
        t.row([
            policy.name().to_string(),
            format!("{:.1}", out.report.throughput_tps),
            format!("{:.2}s", out.report.ttft_p50_s),
            format!("{:.1}ms", out.report.tpot_p50_s * 1e3),
            format!("{}", out.counters.scale_ups),
            format!("{}", out.counters.scale_downs),
        ]);
    }
    let st = run_static_hybrid(&cfg, &StaticHybridConfig::paper_default(), &trace);
    t.row([
        "static 1xTP4+4xTP1".to_string(),
        format!("{:.1}", st.report.throughput_tps),
        format!("{:.2}s", st.report.ttft_p50_s),
        format!("{:.1}ms", st.report.tpot_p50_s * 1e3),
        "0".to_string(),
        "0".to_string(),
    ]);
    t.print();
    println!("\n(paper: gyges improves average throughput 26.1%-39.2% over RR/LLF)");
}
