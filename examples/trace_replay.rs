//! Trace replay: generate a production-like trace, write it to CSV,
//! re-load it, and serve it under every system — the §6.3 workflow on
//! your own traces.
//!
//! Run: cargo run --release --example trace_replay [-- --qps 0.6 --horizon 300]
//!      cargo run --release --example trace_replay -- --trace my.csv

use gyges::config::{ClusterConfig, ModelConfig};
use gyges::coordinator::{run_system, SystemKind};
use gyges::util::{Args, Table};
use gyges::workload::Trace;

fn main() -> Result<(), String> {
    let args = Args::from_env();
    let horizon = args.parsed_or("horizon", 300.0);
    let qps = args.parsed_or("qps", 0.6);

    // Load a user CSV or generate + persist one.
    let trace = if let Some(path) = args.get("trace") {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Trace::from_csv(&text)?
    } else {
        let t = Trace::production(args.parsed_or("seed", 99), qps, horizon);
        let path = "target/trace_replay.csv";
        std::fs::create_dir_all("target").ok();
        std::fs::write(path, t.to_csv()).map_err(|e| e.to_string())?;
        println!("generated {} requests -> {path} (re-run with --trace {path})", t.len());
        t
    };
    println!(
        "trace: {} requests, {} tokens total, {} long (>10K input)\n",
        trace.len(),
        trace.total_tokens(),
        trace.long_count(10_000)
    );

    let cfg = ClusterConfig::paper_default(ModelConfig::qwen2_5_32b());
    let mut t =
        Table::new(["system", "tput (tps)", "ttft p50", "ttft p99", "tpot p50", "scale-ups"]);
    for sys in [
        SystemKind::Gyges,
        SystemKind::GygesNoOverlap,
        SystemKind::Basic,
        SystemKind::Seesaw,
        SystemKind::KunServe,
        SystemKind::LoongServe,
    ] {
        let out = run_system(cfg.clone(), sys, None, trace.clone());
        t.row([
            sys.name().to_string(),
            format!("{:.1}", out.report.throughput_tps),
            format!("{:.2}s", out.report.ttft_p50_s),
            format!("{:.2}s", out.report.ttft_p99_s),
            format!("{:.1}ms", out.report.tpot_p50_s * 1e3),
            format!("{}", out.counters.scale_ups),
        ]);
    }
    t.print();
    Ok(())
}
